"""The ordered pipeline's memory model (paper §3.2), property-tested.

Random nbi-op interleavings are replayed through the CommQueue (over
the whole-system LocalTransport) and checked against an oracle that
computes, for every (destination, element), the *maximal-write
candidate set* the paper's model allows:

  * puts complete locally at issue (snapshot semantics),
  * delivery is unordered between ordering points,
  * ``fence`` orders delivery per destination,
  * ``quiet`` completes everything.

The implementation must always land inside the candidate set, for
EVERY delivery interleaving (``delivery_seed`` sweeps legal shuffles),
and locations whose writes the model totally orders must be
seed-invariant.  With hypothesis installed the driver generates 200+
examples; without it a seeded fallback loop covers the same count, so
the suite is meaningful in both environments.

The same sequences replayed on a real 8-PE mesh (PermuteTransport vs
this oracle) live in ``tests/multipe/run_ordering.py``.
"""
import os
import random
import subprocess
import sys

import numpy as np
import pytest

from repro.core import CommQueue, LocalTransport, SymmetricHeap
from repro.core.heap import SymHandle

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_PE = 3
OBJ_LEN = 6
HANDLE = SymHandle("buf", (OBJ_LEN,), np.dtype(np.float32), 0,
                   OBJ_LEN * 4)
SEEDS = (None, 0, 1, 7)


# ======================================================================
# random-sequence generator + the paper-model oracle
# ======================================================================
def gen_sequence(rng: random.Random):
    """A random issue sequence: puts (random partial permutations,
    random offsets/extents, unique payload values), per-destination and
    global fences.  A final quiet is implicit in the checker."""
    events = []
    val = 0
    for _ in range(rng.randint(1, 14)):
        kind = rng.choices(["put", "fence", "fence_all"],
                           weights=[6, 2, 1])[0]
        if kind == "put":
            k = rng.randint(1, N_PE)
            srcs = rng.sample(range(N_PE), k)
            dsts = rng.sample(range(N_PE), k)
            pairs = list(zip(srcs, dsts))
            offset = rng.randint(0, OBJ_LEN - 1)
            rows = rng.randint(1, OBJ_LEN - offset)
            val += 1
            # unique value per (put, source): payload row s = 100*val+s
            values = {s: 100.0 * val + s for s, _ in pairs}
            events.append(("put", pairs, offset, rows, values))
        elif kind == "fence":
            events.append(("fence", rng.randrange(N_PE)))
        else:
            events.append(("fence", None))
    return events


def oracle_candidates(events):
    """For each (dst, elem): the set of payload values the model allows
    as the final memory contents — the maximal elements of the
    fence-induced partial order over the writes to that location."""
    # the implicit final quiet orders like a fence covering every dst
    evs = list(events) + [("fence", None)]
    cands = {}
    for d in range(N_PE):
        fpos = [i for i, e in enumerate(evs)
                if e[0] == "fence" and (e[1] is None or e[1] == d)]
        for elem in range(OBJ_LEN):
            writes = []                       # (issue index, value)
            for i, e in enumerate(evs):
                if e[0] != "put":
                    continue
                _, pairs, off, rows, values = e
                if not (off <= elem < off + rows):
                    continue
                for s, dd in pairs:
                    if dd == d:
                        writes.append((i, values[s] + (elem - off) / 16.0))
            if not writes:
                continue
            maximal = set()
            for i, v in writes:
                later_fences = [f for f in fpos if f > i]
                first_f = min(later_fences) if later_fences else None
                if first_f is None or not any(j > first_f
                                              for j, _ in writes):
                    maximal.add(v)
            cands[(d, elem)] = maximal
    return cands


def run_impl(events, seed):
    """Replay a sequence through the CommQueue + LocalTransport;
    returns the final (n_pe, OBJ_LEN) system state."""
    state = {"buf": np.zeros((N_PE, OBJ_LEN), np.float32)}
    q = CommQueue("pe", state, transport=LocalTransport(N_PE),
                  delivery_seed=seed)
    for e in events:
        if e[0] == "put":
            _, pairs, offset, rows, values = e
            data = np.zeros((N_PE, rows), np.float32)
            for s, _ in pairs:
                data[s] = values[s] + np.arange(rows, dtype=np.float32) / 16.0
            q.put_nbi(HANDLE, data, pairs, offset=offset)
            # local completion: the source buffer is reusable the moment
            # put_nbi returns — scribbling on it must not alter delivery
            data.fill(-999.0)
        else:
            q.fence(e[1])
    out = q.quiet()
    assert q.pending_ops() == 0
    return np.asarray(out["buf"])


def check_sequence(events):
    cands = oracle_candidates(events)
    finals = {}
    for seed in SEEDS:
        buf = run_impl(events, seed)
        finals[seed] = buf
        for d in range(N_PE):
            for elem in range(OBJ_LEN):
                got = float(buf[d, elem])
                allowed = cands.get((d, elem))
                if allowed is None:
                    assert got == 0.0, (d, elem, got)   # never written
                else:
                    assert got in allowed, \
                        f"dst {d} elem {elem}: {got} not in {allowed} " \
                        f"(seed {seed})"
    # totally-ordered locations are delivery-interleaving invariant
    for (d, elem), allowed in cands.items():
        if len(allowed) == 1:
            vals = {float(finals[s][d, elem]) for s in SEEDS}
            assert len(vals) == 1, (d, elem, vals)


# ======================================================================
# the property test — 200+ examples with or without hypothesis
# ======================================================================
if HAVE_HYPOTHESIS:
    @pytest.mark.shmem_racy        # replays deliberately-racy sequences
    @settings(max_examples=220, deadline=None)
    @given(st.integers(0, 2 ** 32 - 1))
    def test_ordering_model_property(seed):
        check_sequence(gen_sequence(random.Random(seed)))
else:
    @pytest.mark.shmem_racy        # replays deliberately-racy sequences
    @pytest.mark.parametrize("chunk", range(11))
    def test_ordering_model_property(chunk):
        # 11 chunks x 20 sequences = 220 examples, hypothesis-free
        for i in range(20):
            check_sequence(gen_sequence(random.Random(chunk * 20 + i)))


# ======================================================================
# directed unit tests for the documented guarantees
# ======================================================================
def _queue(seed=None):
    state = {"buf": np.zeros((N_PE, OBJ_LEN), np.float32)}
    return CommQueue("pe", state, transport=LocalTransport(N_PE),
                     delivery_seed=seed)


def _payload(src, value, rows=1):
    data = np.zeros((N_PE, rows), np.float32)
    data[src] = value
    return data


def test_fence_orders_same_destination():
    """put A ; fence ; put B (same dst, same loc) -> B wins, every
    interleaving (the §3.2 fence guarantee)."""
    for seed in SEEDS:
        q = _queue(seed)
        q.put_nbi(HANDLE, _payload(0, 1.0), [(0, 2)])
        q.fence()
        q.put_nbi(HANDLE, _payload(1, 2.0), [(1, 2)])
        buf = np.asarray(q.quiet()["buf"])
        assert buf[2, 0] == 2.0


@pytest.mark.shmem_racy            # reads state with a put in flight
def test_per_destination_fence_only_orders_that_destination():
    q = _queue(0)
    q.put_nbi(HANDLE, _payload(0, 1.0), [(0, 2)])
    q.put_nbi(HANDLE, _payload(0, 5.0), [(0, 1)])
    q.fence(dst=2)                      # drains only the dst-2 put
    assert q.pending_ops() == 1
    assert np.asarray(q.state["buf"])[2, 0] == 1.0
    assert np.asarray(q.state["buf"])[1, 0] == 0.0   # still pending
    q.quiet()
    assert np.asarray(q.state["buf"])[1, 0] == 5.0


@pytest.mark.shmem_racy            # reads state with a put in flight
def test_pending_invisible_until_drain():
    """Delivery does not happen at issue: state is unchanged until a
    drain point covers the destination."""
    q = _queue()
    q.put_nbi(HANDLE, _payload(0, 3.0), [(0, 1)])
    assert q.pending_ops() == 1
    assert np.asarray(q.state["buf"]).sum() == 0.0
    q.quiet()
    assert np.asarray(q.state["buf"])[1, 0] == 3.0


def test_get_nbi_reads_post_drain_state():
    q = _queue()
    q.put_nbi(HANDLE, _payload(0, 7.0, rows=2), [(0, 1)], offset=2)
    res = q.get_nbi(HANDLE, [(1, 0)], offset=2, size=2)   # PE0 reads PE1
    with pytest.raises(RuntimeError, match="before quiet"):
        res.value()                     # undefined before the barrier
    assert not res.ready
    q.quiet()
    assert res.ready
    np.testing.assert_allclose(np.asarray(res.value())[0], [7.0, 7.0])


def test_get_nbi_default_size_is_rest_of_object():
    """size=None with a static offset reads offset..end — resolved at
    issue time so both transports agree on the extent."""
    q = _queue()
    q.put_nbi(HANDLE, _payload(0, 9.0, rows=OBJ_LEN), [(0, 1)])
    res = q.get_nbi(HANDLE, [(1, 2)], offset=2)           # rest: 4 rows
    q.quiet()
    got = np.asarray(res.value())
    assert got.shape == (N_PE, OBJ_LEN - 2)
    np.testing.assert_allclose(got[2], 9.0)
    with pytest.raises(ValueError, match="leaves no rows"):
        q.get_nbi(HANDLE, [(1, 2)], offset=OBJ_LEN)


def test_queue_stats_and_free_functions():
    from repro.core import fence, get_nbi, put_nbi, quiet
    q = _queue()
    put_nbi(q, HANDLE, _payload(0, 1.0), [(0, 1)])
    r = get_nbi(q, HANDLE, [(1, 0)], size=1)
    fence(q)
    quiet(q)
    st = q.stats()
    assert st["puts"] == 1 and st["gets"] == 1
    assert st["fences"] == 1 and st["quiets"] == 1
    assert st["drains"] == 2                     # fences + quiets
    assert st["pending_by_dst"] == {}            # fully drained queue
    assert st["drained"] == 2 and st["max_pending"] == 2
    assert r.ready


def test_stats_pending_by_dst_tracks_undrained_puts():
    """The stats contract the analysis tooling keys on: per-destination
    pending counts shrink with per-dst fences, drains counts every
    happens-before edge."""
    q = _queue()
    q.put_nbi(HANDLE, _payload(0, 1.0), [(0, 1)])
    q.put_nbi(HANDLE, _payload(0, 2.0), [(0, 2)], offset=1)
    q.put_nbi(HANDLE, _payload(0, 3.0), [(0, 2)], offset=3)
    assert q.stats()["pending_by_dst"] == {1: 1, 2: 2}
    assert q.stats()["drains"] == 0
    q.fence(dst=2)
    assert q.stats()["pending_by_dst"] == {1: 1}
    assert q.stats()["drains"] == 1
    q.quiet()
    assert q.stats()["pending_by_dst"] == {}
    assert q.stats()["drains"] == 2


class _CountingTransport(LocalTransport):
    """LocalTransport that counts actual delivery rounds."""

    def __init__(self, n_pe):
        super().__init__(n_pe)
        self.rounds = 0

    def put(self, *a, **k):
        self.rounds += 1
        return super().put(*a, **k)


def test_drain_coalesces_contiguous_same_destination_puts():
    """N contiguous puts through the same pairs merge into ONE
    transport round at the drain (the ROADMAP coalescing item), with
    the final state unchanged."""
    tr = _CountingTransport(N_PE)
    q = CommQueue("pe", {"buf": np.zeros((N_PE, OBJ_LEN), np.float32)},
                  transport=tr)
    for i in range(4):
        q.put_nbi(HANDLE, _payload(0, 10.0 + i), [(0, 1)], offset=i)
    q.quiet()
    assert tr.rounds == 1
    assert q.stats()["coalesced"] == 3
    np.testing.assert_allclose(np.asarray(q.state["buf"])[1, :4],
                               [10.0, 11.0, 12.0, 13.0])


def test_phase_attribution_accumulates_and_rejects_nesting():
    """``CommQueue.phase(name)`` attributes counter deltas to a named
    window: re-entries ACCUMULATE (the weight hot-swap streamer opens
    its "swap" phase once per serving tick and reads one running
    account), ops outside any phase stay unattributed, and nesting is
    rejected — a delta may only be attributed once."""
    q = CommQueue("pe", {"buf": np.zeros((N_PE, OBJ_LEN), np.float32)},
                  transport=LocalTransport(N_PE))
    with q.phase("swap"):
        q.put_nbi(HANDLE, _payload(0, 1.0), [(0, 1)], offset=0)
    q.put_nbi(HANDLE, _payload(0, 2.0), [(0, 1)], offset=1)  # outside
    q.quiet()                                                # outside
    with q.phase("swap"):                    # re-entry: accumulates
        q.put_nbi(HANDLE, _payload(0, 3.0), [(0, 1)], offset=2)
        q.quiet()
    ph = q.phase_stats("swap")
    assert ph["puts"] == 2 and ph["quiets"] == 1, ph
    assert q.stats()["phases"]["swap"]["puts"] == 2
    # the queue-wide counters still see everything
    assert q.stats()["puts"] == 3 and q.stats()["quiets"] == 2
    # a phase that never ran reads as all-zero deltas
    assert not any(q.phase_stats("never").values())
    with q.phase("outer"):
        with pytest.raises(RuntimeError, match="do not nest"):
            with q.phase("inner"):
                pass  # pragma: no cover


def test_drain_does_not_coalesce_across_pairs_or_gaps():
    """Different pair lists, non-contiguous offsets and different
    handles stay separate rounds — coalescing must never weaken the
    addressing."""
    tr = _CountingTransport(N_PE)
    q = CommQueue("pe", {"buf": np.zeros((N_PE, OBJ_LEN), np.float32)},
                  transport=tr)
    q.put_nbi(HANDLE, _payload(0, 1.0), [(0, 1)], offset=0)
    q.put_nbi(HANDLE, _payload(0, 2.0), [(0, 2)], offset=1)   # other dst
    q.put_nbi(HANDLE, _payload(0, 3.0), [(0, 2)], offset=3)   # gap
    q.quiet()
    assert tr.rounds == 3
    assert q.stats()["coalesced"] == 0
    buf = np.asarray(q.state["buf"])
    assert buf[1, 0] == 1.0 and buf[2, 1] == 2.0 and buf[2, 3] == 3.0


@pytest.mark.shmem_racy            # replays deliberately-racy sequences
def test_coalesced_drain_matches_uncoalesced_under_shuffle():
    """Coalescing is an implementation detail: for every delivery seed
    the coalesced drain produces the same final state as an opted-out
    transport (concat_puts -> None)."""

    class NoCoalesce(LocalTransport):
        def concat_puts(self, datas):
            return None

    rng = random.Random(123)
    for case in range(20):
        events = gen_sequence(rng)
        for seed in SEEDS:
            states = []
            for tr in (LocalTransport(N_PE), NoCoalesce(N_PE)):
                q = CommQueue("pe",
                              {"buf": np.zeros((N_PE, OBJ_LEN),
                                               np.float32)},
                              transport=tr, delivery_seed=seed)
                for e in events:
                    if e[0] == "put":
                        _, pairs, offset, rows, values = e
                        data = np.zeros((N_PE, rows), np.float32)
                        for s, _ in pairs:
                            data[s] = values[s] + \
                                np.arange(rows, dtype=np.float32) / 16.0
                        q.put_nbi(HANDLE, data, pairs, offset=offset)
                    else:
                        q.fence(e[1])
                states.append(np.asarray(q.quiet()["buf"]))
            np.testing.assert_array_equal(states[0], states[1])


def test_allreduce_nbi_issue_order_and_barrier():
    log = []

    def deliver(tag):
        def f(x):
            log.append(tag)
            return x * 2
        return f

    q = CommQueue("pe", {}, transport=LocalTransport(N_PE),
                  delivery_seed=3)     # seed shuffles puts, never reduces
    ra = q.allreduce_nbi(np.full(3, 1.0), deliver("a"))
    rb = q.allreduce_nbi(np.full(3, 2.0), deliver("b"))
    with pytest.raises(RuntimeError):
        ra.value()
    q.quiet()
    assert log == ["a", "b"]            # issue order at the drain
    np.testing.assert_allclose(ra.value(), 2.0)
    np.testing.assert_allclose(rb.value(), 4.0)


# ======================================================================
# put-with-signal: payload-before-signal + the per-transfer drain,
# property-tested against the same maximal-write oracle
# ======================================================================
N_SIG = 4
SIG_HANDLE = SymHandle("sig", (N_SIG,), np.dtype(np.int64), 256,
                       N_SIG * 8)
# payload rows are partitioned so the property's assertions are exact:
# plain puts write rows [0, _SIG_ROW0), each put-with-signal owns ONE
# unique row in [_SIG_ROW0, OBJ_LEN)
_SIG_ROW0 = 3


def gen_signal_sequence(rng: random.Random):
    """Random issue sequence mixing plain puts, fences and
    put-with-signals (unique payload row per put-signal, signal words
    drawn from a small pad, value always 1)."""
    events = []
    val = 0
    sig_rows = list(range(_SIG_ROW0, OBJ_LEN))
    rng.shuffle(sig_rows)
    for _ in range(rng.randint(2, 12)):
        kind = rng.choices(["put", "fence", "putsig"],
                           weights=[5, 2, 4])[0]
        if kind == "putsig" and not sig_rows:
            kind = "put"
        if kind == "put":
            k = rng.randint(1, N_PE)
            pairs = list(zip(rng.sample(range(N_PE), k),
                             rng.sample(range(N_PE), k)))
            offset = rng.randrange(_SIG_ROW0)
            rows = rng.randint(1, _SIG_ROW0 - offset)
            val += 1
            values = {s: 100.0 * val + s for s, _ in pairs}
            events.append(("put", pairs, offset, rows, values))
        elif kind == "fence":
            events.append(("fence", rng.choice([None] +
                                               list(range(N_PE)))))
        else:
            k = rng.randint(1, N_PE)
            pairs = list(zip(rng.sample(range(N_PE), k),
                             rng.sample(range(N_PE), k)))
            val += 1
            values = {s: 100.0 * val + s for s, _ in pairs}
            events.append(("putsig", pairs, sig_rows.pop(), values,
                           rng.randrange(N_SIG)))
    return events


def _as_put_events(events):
    """The oracle's view: a put-with-signal's payload is a 1-row put
    (the signal word lives in a different object the buf oracle never
    sees)."""
    out = []
    for e in events:
        if e[0] == "putsig":
            _, pairs, off, values, _word = e
            out.append(("put", pairs, off, 1, values))
        else:
            out.append(e)
    return out


def check_signal_sequence(events):
    """Replay per seed; fire ONE signal_wait_until mid-stream and pin
    its contract — the guarded payloads (and only they) become
    visible — then quiet and check the final state against the PR-2
    maximal-write oracle."""
    cands = oracle_candidates(_as_put_events(events))
    finals = {}
    for seed in SEEDS:
        state = {"buf": np.zeros((N_PE, OBJ_LEN), np.float32),
                 "sig": np.zeros((N_PE, N_SIG), np.int64)}
        q = CommQueue("pe", state, transport=LocalTransport(N_PE),
                      delivery_seed=seed)
        pend = []                        # mirror of the queue's pending ops
        for e in events:
            if e[0] == "put":
                _, pairs, offset, rows, values = e
                data = np.zeros((N_PE, rows), np.float32)
                for s, _ in pairs:
                    data[s] = values[s] + \
                        np.arange(rows, dtype=np.float32) / 16.0
                q.put_nbi(HANDLE, data, pairs, offset=offset)
                data.fill(-999.0)        # local completion
                pend.append(e)
            elif e[0] == "fence":
                q.fence(e[1])
                pend = [p for p in pend
                        if e[1] is not None
                        and e[1] not in {d for _, d in p[1]}]
            else:
                _, pairs, off, values, word = e
                data = np.zeros((N_PE, 1), np.float32)
                for s, _ in pairs:
                    data[s] = values[s]
                q.put_signal_nbi(HANDLE, data, pairs, SIG_HANDLE, 1,
                                 offset=off, sig_offset=word)
                data.fill(-999.0)        # local completion
                pend.append(e)
        guarded = [p for p in pend if p[0] == "putsig"]
        if guarded:
            word = guarded[0][4]
            mine = [p for p in guarded if p[4] == word]
            before = {k: np.array(v) for k, v in q.state.items()}
            pe = mine[0][1][0][1]        # a dst of a guarded put
            q.signal_wait_until(SIG_HANDLE, "ne", 0, sig_offset=word,
                                pe=pe)
            after = q.state
            # the guarded payloads are visible ...
            touched_buf, touched_sig = set(), set()
            for _, pairs, off, values, _w in mine:
                for s, d in pairs:
                    assert after["buf"][d, off] == values[s], \
                        f"seed {seed}: guarded payload not visible"
                    assert after["sig"][d, word] == 1
                    touched_buf.add((d, off))
                    touched_sig.add((d, word))
            # ... and ONLY they: nothing else moved at the wait
            diff_buf = {tuple(i) for i in
                        np.argwhere(before["buf"] != after["buf"])}
            diff_sig = {tuple(i) for i in
                        np.argwhere(before["sig"] != after["sig"])}
            assert diff_buf <= touched_buf, (seed, diff_buf, touched_buf)
            assert diff_sig <= touched_sig, (seed, diff_sig, touched_sig)
        buf = np.asarray(q.quiet()["buf"])
        assert q.pending_ops() == 0
        finals[seed] = buf
        for d in range(N_PE):
            for elem in range(OBJ_LEN):
                got = float(buf[d, elem])
                allowed = cands.get((d, elem))
                if allowed is None:
                    assert got == 0.0, (d, elem, got)
                else:
                    assert got in allowed, \
                        f"dst {d} elem {elem}: {got} not in {allowed} " \
                        f"(seed {seed})"
    for (d, elem), allowed in cands.items():
        if len(allowed) == 1:
            vals = {float(finals[s][d, elem]) for s in SEEDS}
            assert len(vals) == 1, (d, elem, vals)


if HAVE_HYPOTHESIS:
    @pytest.mark.shmem_racy        # replays deliberately-racy sequences
    @settings(max_examples=220, deadline=None)
    @given(st.integers(0, 2 ** 32 - 1))
    def test_put_signal_model_property(seed):
        check_signal_sequence(gen_signal_sequence(random.Random(seed)))
else:
    @pytest.mark.shmem_racy        # replays deliberately-racy sequences
    @pytest.mark.parametrize("chunk", range(11))
    def test_put_signal_model_property(chunk):
        # 11 chunks x 20 sequences = 220 examples, hypothesis-free
        for i in range(20):
            check_signal_sequence(
                gen_signal_sequence(random.Random(7000 + chunk * 20 + i)))


class _RecordingTransport(LocalTransport):
    """LocalTransport that logs the actual delivery order."""

    def __init__(self, n_pe):
        super().__init__(n_pe)
        self.log = []

    def put(self, state, handle, data, pairs, team, offset):
        self.log.append(("put", handle.name, int(offset),
                         int(np.shape(data)[-1])))
        return super().put(state, handle, data, pairs, team, offset)

    def put_signal(self, state, handle, value, pairs, team, offset, op):
        self.log.append(("signal", handle.name, int(offset)))
        return super().put_signal(state, handle, value, pairs, team,
                                  offset, op)


def _sig_queue(seed=None, transport=None):
    state = {"buf": np.zeros((N_PE, OBJ_LEN), np.float32),
             "sig": np.zeros((N_PE, N_SIG), np.int64)}
    return CommQueue("pe", state,
                     transport=transport or LocalTransport(N_PE),
                     delivery_seed=seed)


def test_payload_delivered_before_signal_every_shuffle():
    """The one ordering edge put-with-signal adds: within any drain,
    for every legal delivery shuffle, the signal word lands AFTER its
    payload (everything else still shuffles freely)."""
    for seed in list(range(30)) + [None]:
        tr = _RecordingTransport(N_PE)
        q = _sig_queue(seed, transport=tr)
        for w in range(OBJ_LEN - _SIG_ROW0):
            q.put_nbi(HANDLE, _payload(0, 50.0 + w), [(0, 1)], offset=0)
            q.put_signal_nbi(HANDLE, _payload(0, 1.0 + w), [(0, 1)],
                             SIG_HANDLE, 1, offset=_SIG_ROW0 + w,
                             sig_offset=w)
        q.quiet()
        for w in range(OBJ_LEN - _SIG_ROW0):
            # coalescing may fold the payload into a wider run; find
            # the delivery that covers its row
            pay = next(i for i, e in enumerate(tr.log)
                       if e[0] == "put" and e[1] == "buf"
                       and e[2] <= _SIG_ROW0 + w < e[2] + e[3])
            sig = tr.log.index(("signal", "sig", w))
            assert pay < sig, (seed, w, tr.log)


@pytest.mark.shmem_racy            # reads state with puts in flight
def test_signal_wait_drains_only_the_guarded_transfer():
    """signal_wait_until is PER-TRANSFER completion: the guarded
    payload+signal deliver, every unrelated pending put stays pending
    (no hidden quiet)."""
    for seed in SEEDS:
        q = _sig_queue(seed)
        q.put_nbi(HANDLE, _payload(0, 9.0), [(0, 2)], offset=0)
        q.put_signal_nbi(HANDLE, _payload(0, 5.0), [(0, 1)], SIG_HANDLE,
                         7, offset=3, sig_offset=1)
        q.put_signal_nbi(HANDLE, _payload(0, 6.0), [(0, 1)], SIG_HANDLE,
                         8, offset=4, sig_offset=2)
        q.signal_wait_until(SIG_HANDLE, "eq", 7, sig_offset=1, pe=1)
        buf = np.asarray(q.state["buf"])
        sig = np.asarray(q.state["sig"])
        assert buf[1, 3] == 5.0 and sig[1, 1] == 7    # guarded: visible
        assert buf[2, 0] == 0.0                       # plain put: pending
        assert buf[1, 4] == 0.0 and sig[1, 2] == 0    # other ticket: pending
        assert q.pending_ops() == 3                   # put + other pair
        q.quiet()
        assert np.asarray(q.state["buf"])[2, 0] == 9.0
        assert np.asarray(q.state["sig"])[1, 2] == 8


def test_signal_wait_without_pending_guard():
    """A wait on an already-satisfied word (its guard drained earlier
    by a covering fence/quiet) returns immediately; an unsatisfiable
    wait raises instead of spinning forever."""
    q = _sig_queue()
    q.put_signal_nbi(HANDLE, _payload(0, 2.0), [(0, 1)], SIG_HANDLE, 3,
                     offset=3, sig_offset=0)
    q.quiet()                        # drains payload AND signal
    st = q.signal_wait_until(SIG_HANDLE, "eq", 3, sig_offset=0, pe=1)
    assert st["buf"][1, 3] == 2.0
    with pytest.raises(RuntimeError, match="block forever"):
        q.signal_wait_until(SIG_HANDLE, "eq", 99, sig_offset=0, pe=1)


def test_signal_add_accumulates_per_page_idiom():
    """SIGNAL_ADD: one word counts N guarded transfers; the consumer
    waits CMP_GE N (the multi-page handoff-ticket idiom)."""
    for seed in SEEDS:
        q = _sig_queue(seed)
        for i in range(3):
            q.put_signal_nbi(HANDLE, _payload(0, 10.0 + i), [(0, 2)],
                             SIG_HANDLE, 1, offset=_SIG_ROW0 + i,
                             sig_offset=3, sig_op="add")
        st = q.signal_wait_until(SIG_HANDLE, "ge", 3, sig_offset=3, pe=2)
        assert st["sig"][2, 3] == 3
        np.testing.assert_allclose(st["buf"][2, _SIG_ROW0:_SIG_ROW0 + 3],
                                   [10.0, 11.0, 12.0])


def test_signal_stats_and_free_functions():
    from repro.core import (CMP_EQ, SignalPad, put_signal_nbi,
                            signal_wait_until)
    q = _sig_queue()
    put_signal_nbi(q, HANDLE, _payload(0, 1.0), [(0, 1)], SIG_HANDLE, 1,
                   offset=3, sig_offset=0)
    signal_wait_until(q, SIG_HANDLE, CMP_EQ, 1, sig_offset=0, pe=1)
    st = q.stats()
    assert st["signal_puts"] == 1 and st["signal_waits"] == 1
    assert st["quiets"] == 0         # per-transfer drain, no barrier
    assert st["drained"] == 2        # payload + signal word
    assert q.pending_ops() == 0
    # SignalPad: symmetric words, identical offsets across
    # identically-driven heaps (Fact 1), round-robin ticket words
    pads = []
    for _ in range(2):
        h = SymmetricHeap(("data",), capacity_bytes=1 << 20)
        h.alloc("kv", (8, 4), np.float32)
        pads.append(SignalPad(h, 6))
    assert pads[0].handle.offset == pads[1].handle.offset
    assert pads[0].word(2) == 2 and pads[0].word(8) == 2
    assert pads[0].zeros().shape == (6,)
    with pytest.raises(ValueError):
        q.put_signal_nbi(HANDLE, _payload(0, 1.0), [(0, 1)], SIG_HANDLE,
                         1, sig_op="bogus")
    with pytest.raises(ValueError, match="unknown signal comparison"):
        q.signal_wait_until(SIG_HANDLE, "??", 0, sig_offset=0, pe=0)


# ======================================================================
# queue AMOs (§4.6): each op its own linearization point inside the
# delivery shuffle, drained per-word by amo_wait — property-tested
# against the same maximal-write oracle plus a brute-force
# linearizability check on every counter cell
# ======================================================================
N_CTR = 4
CTR_HANDLE = SymHandle("ctr", (N_CTR,), np.dtype(np.int64), 512,
                       N_CTR * 8)


def gen_amo_sequence(rng: random.Random):
    """Random issue sequence mixing plain puts (buf), fences and AMOs
    on counter words (ctr) — at most 5 AMOs per (owner, word) cell so
    the linearizability check can brute-force every order."""
    events = []
    val = 0
    per_cell: dict = {}
    for _ in range(rng.randint(2, 14)):
        kind = rng.choices(["put", "fence", "amo"], weights=[4, 2, 5])[0]
        if kind == "put":
            k = rng.randint(1, N_PE)
            pairs = list(zip(rng.sample(range(N_PE), k),
                             rng.sample(range(N_PE), k)))
            offset = rng.randint(0, OBJ_LEN - 1)
            rows = rng.randint(1, OBJ_LEN - offset)
            val += 1
            values = {s: 100.0 * val + s for s, _ in pairs}
            events.append(("put", pairs, offset, rows, values))
        elif kind == "fence":
            events.append(("fence", rng.choice([None] +
                                               list(range(N_PE)))))
        else:
            word = rng.randrange(N_CTR)
            owner = rng.randrange(N_PE)
            if per_cell.get((owner, word), 0) >= 5:
                continue
            per_cell[(owner, word)] = per_cell.get((owner, word), 0) + 1
            op = rng.choice(["fadd", "swap", "cswap", "fetch"])
            value = rng.randint(1, 9) if op != "fetch" else None
            cond = rng.randint(0, 9) if op == "cswap" else None
            events.append(("amo", op, (rng.randrange(N_PE), owner),
                           word, value, cond))
    return events


def _amo_apply(cur, op, value, cond):
    if op == "fadd":
        return cur + value
    if op == "swap":
        return value
    if op == "cswap":
        return value if cur == cond else cur
    return cur                             # fetch


def _linearizable(history, final):
    """Does SOME total order of ``history`` (op, value, cond, old)
    starting from 0 reproduce every fetched old value and the final
    cell?  len(history) <= 5, so brute force is cheap."""
    import itertools
    for perm in itertools.permutations(range(len(history))):
        cur = 0
        for i in perm:
            op, value, cond, old = history[i]
            if old != cur:
                break
            cur = _amo_apply(cur, op, value, cond)
        else:
            if cur == final:
                return True
    return False


def check_amo_sequence(events):
    cands = oracle_candidates(
        [e for e in events if e[0] in ("put", "fence")])
    finals = {}
    for seed in SEEDS:
        state = {"buf": np.zeros((N_PE, OBJ_LEN), np.float32),
                 "ctr": np.zeros((N_PE, N_CTR), np.int64)}
        q = CommQueue("pe", state, transport=LocalTransport(N_PE),
                      delivery_seed=seed)
        issued = []                # (owner, word, op, value, cond, res)
        for e in events:
            if e[0] == "put":
                _, pairs, offset, rows, values = e
                data = np.zeros((N_PE, rows), np.float32)
                for s, _ in pairs:
                    data[s] = values[s] + \
                        np.arange(rows, dtype=np.float32) / 16.0
                q.put_nbi(HANDLE, data, pairs, offset=offset)
                data.fill(-999.0)
            elif e[0] == "fence":
                q.fence(e[1])
            else:
                _, op, (src, owner), word, value, cond = e
                r = q.amo_nbi(CTR_HANDLE, op, [(src, owner)],
                              value=value, cond=cond, offset=word)
                issued.append((owner, word, op, value, cond, r))
        for word in range(N_CTR):
            q.amo_wait(CTR_HANDLE, offset=word)
        # per-word waits retired EVERY amo — readable before the quiet
        assert all(r.ready for *_ignored, r in issued), seed
        buf = np.asarray(q.quiet()["buf"])
        ctr = np.asarray(q.state["ctr"])
        finals[seed] = buf
        hist: dict = {}
        for owner, word, op, value, cond, r in issued:
            hist.setdefault((owner, word), []).append(
                (op, value, cond, int(r.value())))
        for (owner, word), h in hist.items():
            assert _linearizable(h, int(ctr[owner, word])), \
                f"seed {seed} cell ({owner},{word}): {h} final " \
                f"{int(ctr[owner, word])} not linearizable"
        for d in range(N_PE):
            for elem in range(OBJ_LEN):
                got = float(buf[d, elem])
                allowed = cands.get((d, elem))
                if allowed is None:
                    assert got == 0.0, (d, elem, got)
                else:
                    assert got in allowed, \
                        f"dst {d} elem {elem}: {got} not in {allowed} " \
                        f"(seed {seed})"
    for (d, elem), allowed in cands.items():
        if len(allowed) == 1:
            vals = {float(finals[s][d, elem]) for s in SEEDS}
            assert len(vals) == 1, (d, elem, vals)


if HAVE_HYPOTHESIS:
    @pytest.mark.shmem_racy        # replays deliberately-racy sequences
    @settings(max_examples=220, deadline=None)
    @given(st.integers(0, 2 ** 32 - 1))
    def test_amo_model_property(seed):
        check_amo_sequence(gen_amo_sequence(random.Random(seed)))
else:
    @pytest.mark.shmem_racy        # replays deliberately-racy sequences
    @pytest.mark.parametrize("chunk", range(11))
    def test_amo_model_property(chunk):
        # 11 chunks x 20 sequences = 220 examples, hypothesis-free
        for i in range(20):
            check_amo_sequence(
                gen_amo_sequence(random.Random(9000 + chunk * 20 + i)))


def _ctr_queue(seed=None):
    state = {"buf": np.zeros((N_PE, OBJ_LEN), np.float32),
             "ctr": np.zeros((N_PE, N_CTR), np.int64)}
    return CommQueue("pe", state, transport=LocalTransport(N_PE),
                     delivery_seed=seed)


def test_amo_fadd_chain_linearizes_every_shuffle():
    """K pending fadd(+1) on one word: for every delivery seed the
    fetched old values are a permutation of 0..K-1 and the cell ends at
    K — and the shuffle really is the linearization (different seeds
    produce different permutations)."""
    orders = set()
    for seed in list(range(30)) + [None]:
        q = _ctr_queue(seed)
        rs = [q.amo_nbi(CTR_HANDLE, "fadd", [(s, 2)], value=1)
              for s in range(N_PE)] + \
             [q.amo_nbi(CTR_HANDLE, "fadd", [(0, 2)], value=1)
              for _ in range(2)]
        assert not any(r.ready for r in rs)
        q.amo_wait(CTR_HANDLE)
        olds = [int(r.value()) for r in rs]
        assert sorted(olds) == list(range(len(rs))), (seed, olds)
        assert int(np.asarray(q.state["ctr"])[2, 0]) == len(rs)
        orders.add(tuple(olds))
    assert len(orders) > 1             # the shuffle linearizes


def test_amo_cswap_exactly_one_winner():
    """Competing cswaps with the same cond: exactly one observes the
    pristine word; the final value is the winner's, every shuffle."""
    for seed in SEEDS:
        q = _ctr_queue(seed)
        rs = [q.amo_nbi(CTR_HANDLE, "cswap", [(s, 1)], value=10 + s,
                        cond=0, offset=2) for s in range(N_PE)]
        q.amo_wait(CTR_HANDLE, offset=2)
        olds = [int(r.value()) for r in rs]
        winners = [s for s, old in enumerate(olds) if old == 0]
        assert len(winners) == 1, (seed, olds)
        w = winners[0]
        assert int(np.asarray(q.state["ctr"])[1, 2]) == 10 + w
        # every loser fetched the winner's published value
        assert all(olds[s] == 10 + w for s in range(N_PE) if s != w)


@pytest.mark.shmem_racy            # reads state with ops in flight
def test_amo_wait_retires_only_its_word():
    """amo_wait is per-word completion: AMOs on other words and plain
    puts stay pending — the zero-quiet allocator contract."""
    q = _ctr_queue(0)
    q.put_nbi(HANDLE, _payload(0, 4.0), [(0, 2)])
    r0 = q.amo_nbi(CTR_HANDLE, "fadd", [(0, 1)], value=5, offset=0)
    r1 = q.amo_nbi(CTR_HANDLE, "fadd", [(0, 1)], value=7, offset=1)
    q.amo_wait(CTR_HANDLE, offset=0)
    assert r0.ready and int(r0.value()) == 0
    assert not r1.ready
    assert q.pending_ops() == 2        # put + word-1 AMO untouched
    assert np.asarray(q.state["buf"])[2, 0] == 0.0
    assert np.asarray(q.state["ctr"])[1, 1] == 0
    q.quiet()                          # covering drain retires the rest
    assert int(r1.value()) == 0
    assert np.asarray(q.state["ctr"])[1, 1] == 7
    assert np.asarray(q.state["buf"])[2, 0] == 4.0


def test_amo_validation_errors():
    q = _ctr_queue()
    with pytest.raises(ValueError, match="exactly one"):
        q.amo_nbi(CTR_HANDLE, "fadd", [(0, 1), (1, 2)], value=1)
    with pytest.raises(ValueError, match="unknown op"):
        q.amo_nbi(CTR_HANDLE, "xadd", [(0, 1)], value=1)
    with pytest.raises(ValueError, match="cswap needs cond"):
        q.amo_nbi(CTR_HANDLE, "cswap", [(0, 1)], value=1)
    with pytest.raises(ValueError, match="needs value"):
        q.amo_nbi(CTR_HANDLE, "swap", [(0, 1)])
    r = q.amo_nbi(CTR_HANDLE, "fetch", [(0, 1)])
    with pytest.raises(RuntimeError, match="before quiet"):
        r.value()                      # undefined before the drain
    q.quiet()
    assert int(r.value()) == 0


def test_amo_stats_and_free_functions():
    """The stats contract the serve-layer zero-quiet assertions key on:
    amos / amo_waits count issue and drain, a pure AMO workload leaves
    quiets at 0, and the core free functions round-trip."""
    from repro.core import (amo_wait, atomic_cswap_nbi, atomic_fadd_nbi,
                            atomic_fetch_nbi, atomic_swap_nbi)
    q = _ctr_queue()
    ra = atomic_fadd_nbi(q, CTR_HANDLE, 3, [(0, 1)])
    rb = atomic_swap_nbi(q, CTR_HANDLE, 9, [(1, 1)])
    amo_wait(q, CTR_HANDLE)
    rc = atomic_cswap_nbi(q, CTR_HANDLE, 9, 11, [(2, 1)])
    rd = atomic_fetch_nbi(q, CTR_HANDLE, [(0, 1)])
    amo_wait(q, CTR_HANDLE)
    st = q.stats()
    assert st["amos"] == 4 and st["amo_waits"] == 2
    assert st["quiets"] == 0 and st["fences"] == 0
    assert st["drained"] == 4 and st["pending_by_dst"] == {}
    assert {int(ra.value()), int(rb.value())} <= {0, 3}
    assert int(rc.value()) == 9        # swap's 9 published before cswap
    assert int(rd.value()) == 11
    assert q.pending_ops() == 0


def test_signal_reset_goes_through_the_transport():
    """signal_reset recycles a word THROUGH the queue (immediate
    transport write, counted under signal_resets) — the mailbox
    recycling path; host-side dict mutation would diverge from the
    transport's state copy."""
    q = _sig_queue()
    q.put_signal_nbi(HANDLE, _payload(0, 2.0), [(0, 1)], SIG_HANDLE, 5,
                     offset=3, sig_offset=2)
    q.signal_wait_until(SIG_HANDLE, "eq", 5, sig_offset=2, pe=1)
    assert np.asarray(q.state["sig"])[1, 2] == 5
    q.signal_reset(SIG_HANDLE, [(1, 1)], sig_offset=2)
    assert np.asarray(q.state["sig"])[1, 2] == 0    # immediate
    st = q.stats()
    assert st["signal_resets"] == 1
    assert st["signal_puts"] == 1      # a reset is not a transfer
    assert st["quiets"] == 0
    # re-arm: the recycled word carries a fresh guarded transfer
    q.put_signal_nbi(HANDLE, _payload(0, 6.0), [(0, 1)], SIG_HANDLE, 1,
                     offset=4, sig_offset=2)
    q.signal_wait_until(SIG_HANDLE, "eq", 1, sig_offset=2, pe=1)
    assert np.asarray(q.state["buf"])[1, 4] == 6.0


# ======================================================================
# heap addressing used by the queue: O(log n) resolve, boundary-exact
# ======================================================================
def test_resolve_bisect_boundaries():
    h = SymmetricHeap(("data",), capacity_bytes=1 << 20)
    a = h.alloc("a", (16,), np.float32)          # 64 B
    b = h.alloc("b", (8, 2), np.int32)           # 64 B, aligned later
    c = h.alloc("c", (3,), np.int8)              # 3 B
    for handle in (a, b, c):
        first, last = handle.offset, handle.offset + handle.nbytes - 1
        for addr, off in ((first, 0), (last, handle.nbytes - 1)):
            got, goff = h.resolve(addr)
            assert got.name == handle.name and goff == off
    # one past the end of an object falls into padding or the next
    # object — never resolves to the previous one
    for handle in (a, b, c):
        try:
            got, _ = h.resolve(handle.offset + handle.nbytes)
            assert got.name != handle.name
        except KeyError:
            pass
    with pytest.raises(KeyError):
        h.resolve(10 ** 9)
    # freeing resyncs the bisect index: the hole stops resolving,
    # a re-alloc in the hole resolves to the new object
    h.free("b")
    with pytest.raises(KeyError):
        h.resolve(b.offset)
    d = h.alloc("d", (8, 2), np.int32)
    assert d.offset == b.offset                   # first-fit reuse
    got, off = h.resolve(d.offset + 5)
    assert got.name == "d" and off == 5


def test_resolve_many_objects_logn_consistent():
    h = SymmetricHeap(("data",), capacity_bytes=1 << 24)
    handles = [h.alloc(f"o{i}", (i % 7 + 1,), np.float32)
               for i in range(200)]
    rng = random.Random(0)
    for _ in range(300):
        hd = rng.choice(handles)
        byte = rng.randrange(hd.nbytes)
        got, off = h.resolve(hd.offset + byte)
        assert got.name == hd.name and off == byte


# ======================================================================
# the multi-PE suite (PermuteTransport vs oracle + overlapped training)
# ======================================================================
def test_ordering_8pe():
    if os.environ.get("REPRO_MULTIPE_EXPLICIT"):
        pytest.skip("multipe workers run explicitly (scripts/verify.sh)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "multipe", "run_ordering.py")],
        capture_output=True, text=True, env=env, timeout=2400)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ORDERING_PASS" in r.stdout
