"""Checkpointing + fault tolerance: roundtrip, corruption detection,
async save, restart-on-failure, elastic re-mesh planning, stragglers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import Checkpointer, load_checkpoint, save_checkpoint
from repro.ft import (FailureDetector, StragglerPolicy, plan_elastic_remesh,
                      run_with_restarts)


def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"m": jnp.ones((5,)), "count": jnp.zeros((), jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    loaded, step = load_checkpoint(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corruption_detected(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 1, t)
    victim = os.path.join(path, "leaf_0.npy")
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), t)


def test_structure_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"only": jnp.zeros(())})


def test_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        ck.save_async(s, jax.tree.map(lambda x: x + s, t))
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_3", "step_4"]
    loaded, step = ck.restore(t)
    assert step == 4
    np.testing.assert_allclose(np.asarray(loaded["w"]),
                               np.asarray(t["w"]) + 4)


def test_run_with_restarts(tmp_path):
    """Injected failures at steps 7 and 13: the driver restores and
    finishes all 20 steps; the loss stream is the deterministic function
    of the step id (no lost or repeated data)."""
    ck = Checkpointer(str(tmp_path), keep=3)

    def init_state(attempt):
        return {"x": jnp.zeros(())}

    def make_step(attempt):
        def step(state, step_id):
            new = {"x": state["x"] + 1}
            return new, {"loss": 100.0 - step_id}
        return step

    ck.save_async(0, init_state(0))
    ck.wait()
    state, info = run_with_restarts(
        make_step, init_state, ck, n_steps=20,
        failure_schedule={7: RuntimeError("node died"),
                          13: IOError("link flap")},
        ckpt_every=5)
    assert info["restarts"] == 2
    assert info["final_step"] == 20
    # every step contributed exactly once after its final (surviving) run
    assert info["losses"][-1] == 100.0 - 19


def test_failure_detector():
    fd = FailureDetector(n_nodes=4, timeout_s=10.0)
    for n in range(4):
        fd.heartbeat(n, t=0.0)
    assert fd.check(now=5.0) == []
    fd.heartbeat(0, t=11.0)
    fd.heartbeat(1, t=11.0)
    assert fd.check(now=12.0) == [2, 3]
    fd.inject_failure(1)
    assert fd.alive(now=12.0) == [0]


def test_elastic_plan():
    p = plan_elastic_remesh(alive_pods=1, pods=2, data=16, model=16)
    assert p.mesh_shape == (1, 16, 16)
    assert p.dp_size == 16 and p.tp_size == 16
    assert p.dropped_replicas == 16
    with pytest.raises(RuntimeError):
        plan_elastic_remesh(alive_pods=0, pods=2, data=16, model=16)


def test_straggler_policy():
    sp = StragglerPolicy(deadline_s=10.0, demote_after=2)
    assert sp.record(3, 5.0) == "ok"
    assert sp.record(3, 50.0) == "skip"
    assert sp.record(3, 50.0) == "demote"
    assert sp.record(3, 5.0) == "ok"       # reset after success
    assert sp.grad_weight(["ok", "skip", "ok", "ok"]) == pytest.approx(4 / 3)
    assert sp.grad_weight(["skip", "skip"]) == 0.0


def test_elastic_restore_across_mesh(tmp_path):
    """A checkpoint saved under one logical layout loads under another
    (arrays are stored unsharded-logical)."""
    t = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 3, t)
    loaded, _ = load_checkpoint(str(tmp_path), t)
    # re-shard onto a different mesh layout
    from repro import compat
    mesh = compat.make_mesh((1,), ("model",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    arr = jax.device_put(loaded["w"], NamedSharding(mesh, P("model")))
    np.testing.assert_array_equal(np.asarray(arr), np.asarray(t["w"]))
