"""Speculative decoding (serve.spec + the engine verify path).

The acceptance bar: speculation may only change HOW MANY ticks a
stream takes, never the stream — spec-decode token streams must be
bit-identical to non-speculative decoding for greedy AND sampled
requests, alone AND batched, under good, bad and model-backed
proposers (the mesh/backend axis of the same invariant runs in
tests/multipe/run_serve.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, serve
from repro.core.heap import SymmetricHeap
from repro.models import registry
from repro.parallel.ctx import ParallelCtx

PATTERN = [5, 17, 42]


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = registry.build(cfg).init(jax.random.PRNGKey(0), cfg, ctx)
    return params, cfg, ctx


def scfg_of(spec_k, **kw):
    base = dict(page_tokens=4, n_pages=48, max_batch=3, max_seq=32,
                attn_impl="ref")
    base.update(kw)
    return serve.ServeConfig(spec_k=spec_k, **base)


def repeated_reqs(sampling=serve.GREEDY, max_new=16):
    """The repeated-prompt workload: periodic prompts that drive the
    greedy model into self-repetition, where the n-gram proposer
    earns a real accept rate."""
    return [serve.Request(rid=i, prompt=(PATTERN * 4)[:12 - i],
                          max_new=max_new, sampling=sampling)
            for i in range(3)]


def run_engine(model, scfg, reqs, proposer=None):
    params, cfg, ctx = model
    eng = serve.ServeEngine(params, cfg, ctx, scfg, proposer=proposer)
    done = eng.run(reqs, clock="tick")
    return {r.rid: list(r.out) for r in done}, eng


# ======================================================================
# proposers (host-side units)
# ======================================================================
def test_ngram_proposes_repeated_continuation():
    prop = serve.NgramProposer(min_n=1, max_n=3)
    r = serve.Request(rid=0, prompt=[1, 2, 3, 1, 2, 3, 1, 2], max_new=8)
    # suffix 3-gram [3, 1, 2] occurred at index 2 -> continue [3, 1, 2]
    assert prop.propose([r], [3]) == [[3, 1, 2]]
    assert prop.propose([r], [2]) == [[3, 1]]      # allowance cap
    assert prop.propose([r], [0]) == [[]]          # no allowance


def test_ngram_uses_generated_history_and_longest_match():
    prop = serve.NgramProposer(min_n=1, max_n=3)
    r = serve.Request(rid=0, prompt=[7, 8], max_new=8)
    r.out = [9, 4, 9, 4, 9]
    # history 7 8 9 4 9 4 9: suffix [4, 9] -> most recent earlier
    # occurrence ends at index 4, propose [4, 9]
    assert prop.propose([r], [2]) == [[4, 9]]


def test_ngram_no_match_means_no_drafts():
    prop = serve.NgramProposer()
    r = serve.Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new=4)
    assert prop.propose([r], [3]) == [[]]


def test_replay_and_fixed_proposers():
    rep = serve.ReplayProposer({0: [10, 11, 12, 13]})
    r = serve.Request(rid=0, prompt=[1], max_new=8)
    r.out = [10, 11]
    assert rep.propose([r], [3]) == [[12, 13]]     # resumes mid-stream
    fx = serve.FixedProposer([99, 98, 97])
    assert fx.propose([r], [2]) == [[99, 98]]


def test_make_proposer_registry():
    assert isinstance(serve.make_proposer("ngram"), serve.NgramProposer)
    with pytest.raises(ValueError):
        serve.make_proposer("nope")


# ======================================================================
# lossless acceptance: streams are bit-identical to non-spec decoding
# ======================================================================
def test_spec_streams_bit_identical_greedy(model):
    want, base = run_engine(model, scfg_of(0), repeated_reqs())
    got, eng = run_engine(model, scfg_of(3), repeated_reqs())
    assert got == want
    sp = eng.metrics()["spec"]
    # the repeated-prompt workload must actually speculate and win
    assert sp["accept_rate"] > 0
    assert sp["tokens_per_tick"] > 1
    assert eng.ticks < base.ticks                 # fewer ticks, same text


def test_spec_streams_bit_identical_sampled(model):
    samp = serve.SamplingParams(temperature=0.8, top_k=5, top_p=0.9)
    want, _ = run_engine(model, scfg_of(0), repeated_reqs(samp))
    got, eng = run_engine(model, scfg_of(3), repeated_reqs(samp))
    assert got == want
    assert eng.spec_stats["drafted"] > 0          # it did speculate


def test_spec_sampled_alone_equals_batched(model):
    """Batch-composition invariance survives speculation: the verify
    window samples with the same (rid, position) counters regardless
    of batch mates."""
    samp = serve.SamplingParams(temperature=0.8, top_k=5, top_p=0.9)
    full, _ = run_engine(model, scfg_of(3), repeated_reqs(samp))
    alone, _ = run_engine(
        model, scfg_of(3),
        [serve.Request(rid=1, prompt=(PATTERN * 4)[:11], max_new=16,
                       sampling=samp)])
    assert alone[1] == full[1]


def test_replay_oracle_accepts_everything(model):
    """A perfect proposer is fully accepted: k+1 tokens per sequence
    per verify pass, stream unchanged — the deterministic multi-accept
    case."""
    want, _ = run_engine(model, scfg_of(0), repeated_reqs())
    got, eng = run_engine(model, scfg_of(3), repeated_reqs(),
                          proposer=serve.ReplayProposer(want))
    assert got == want
    sp = eng.metrics()["spec"]
    assert sp["accept_rate"] == 1.0
    assert sp["drafted"] == sp["accepted"] > 0
    # every full window emits k+1 = 4 tokens; only budget-capped final
    # windows emit fewer
    assert sp["tokens_per_tick"] > 2


def test_adversarial_proposer_rejects_and_rewinds(model):
    """Every draft wrong: the stream must still be identical (one real
    token per verify pass) and the rejected pages must rewind."""
    want, _ = run_engine(model, scfg_of(0), repeated_reqs())
    # page_tokens=2 so a k=3 verify window regularly crosses a page
    # boundary and rejection frees whole pages
    want2, _ = run_engine(model, scfg_of(0, page_tokens=2),
                          repeated_reqs())
    assert want2 == want
    got, eng = run_engine(model, scfg_of(3, page_tokens=2),
                          repeated_reqs(),
                          proposer=serve.FixedProposer([101, 102, 103]))
    assert got == want
    sp = eng.metrics()["spec"]
    assert sp["accepted"] == 0 and sp["drafted"] > 0
    assert sp["tokens_per_tick"] == 1.0
    assert eng.kv.stats["rewound_pages"] > 0


def test_empty_proposals_degenerate_to_plain_decode(model):
    """The base SpecProposer never proposes: the verify window carries
    n_tok=1 everywhere — plain decode through the verify path.
    (tick_tokens pinned equal: the spec default budget scales with the
    verify window, which would change prefill pacing, not decode.)"""
    want, base = run_engine(model, scfg_of(0, tick_tokens=11),
                            repeated_reqs())
    got, eng = run_engine(model, scfg_of(3, tick_tokens=11),
                          repeated_reqs(),
                          proposer=serve.SpecProposer())
    assert got == want
    assert eng.ticks == base.ticks
    assert eng.spec_stats["drafted"] == 0
    assert eng.kv.stats["rewound_pages"] == 0      # nothing to rewind


def test_spec_composes_with_preemption_and_chunked_prefill(model):
    """Tight pool: speculation's page demand triggers eviction; the
    preempted request re-prefills in chunks and every stream still
    matches the roomy non-speculative run."""
    params, cfg, ctx = model
    prompts = [list(range(2 + i, 10 + i)) for i in range(3)]
    reqs = lambda: [serve.Request(rid=i, prompt=list(p), max_new=8)
                    for i, p in enumerate(prompts)]
    want, _ = run_engine(model, scfg_of(0), reqs())
    got, eng = run_engine(model, scfg_of(3, n_pages=8, prefill_chunk=3),
                          reqs())
    assert got == want
    assert eng.sched.stats["preempted"] > 0        # it was actually tight


def test_spec_with_draft_model_same_params_is_oracle(model):
    """A draft model with the TARGET's own params drafts greedily what
    the target greedily emits — so on greedy traffic every draft is
    accepted (the model-backed analogue of the replay oracle), and the
    stream is untouched."""
    params, cfg, ctx = model
    scfg = scfg_of(3)
    kv = serve.PagedKVCache(
        SymmetricHeap(("data",)), n_layers=cfg.n_layers,
        kv_heads=cfg.kv_per_rank(1), head_dim=cfg.head_dim,
        n_pages=scfg.n_pages, page_tokens=scfg.page_tokens)
    prop = serve.DraftModelProposer(params, cfg, ctx, scfg, kv,
                                    target_vocab=cfg.vocab)
    want, _ = run_engine(model, scfg_of(0), repeated_reqs())
    eng = serve.ServeEngine(params, cfg, ctx, scfg, kv=kv, proposer=prop)
    done = eng.run(repeated_reqs(), clock="tick")
    assert {r.rid: list(r.out) for r in done} == want
    sp = eng.metrics()["spec"]
    assert sp["accept_rate"] == 1.0
    assert sp["tokens_per_tick"] > 2


def test_spec_with_mismatched_draft_model_still_lossless(model):
    """A DIFFERENT-family random draft model gets ~nothing accepted —
    and that must not matter: proposers can only change tick counts,
    never tokens."""
    params, cfg, ctx = model
    scfg = scfg_of(2)
    dcfg = configs.get_smoke("gemma-2b")
    assert dcfg.vocab == cfg.vocab
    kv = serve.PagedKVCache(
        SymmetricHeap(("data",)), n_layers=cfg.n_layers,
        kv_heads=cfg.kv_per_rank(1), head_dim=cfg.head_dim,
        n_pages=scfg.n_pages, page_tokens=scfg.page_tokens)
    dparams = registry.build(dcfg).init(jax.random.PRNGKey(1), dcfg, ctx)
    prop = serve.DraftModelProposer(dparams, dcfg, ctx, scfg, kv,
                                    target_vocab=cfg.vocab)
    want, _ = run_engine(model, scfg_of(0), repeated_reqs(max_new=8))
    eng = serve.ServeEngine(params, cfg, ctx, scfg, kv=kv, proposer=prop)
    done = eng.run(repeated_reqs(max_new=8), clock="tick")
    assert {r.rid: list(r.out) for r in done} == want
    assert eng.spec_stats["drafted"] > 0


def test_draft_model_vocab_mismatch_rejected(model):
    params, cfg, ctx = model
    scfg = scfg_of(2)
    kv = serve.PagedKVCache(
        SymmetricHeap(("data",)), n_layers=cfg.n_layers,
        kv_heads=cfg.kv_per_rank(1), head_dim=cfg.head_dim,
        n_pages=scfg.n_pages, page_tokens=scfg.page_tokens)
    with pytest.raises(ValueError, match="vocab"):
        serve.DraftModelProposer(params, cfg, ctx, scfg, kv,
                                 target_vocab=cfg.vocab + 1)


# ======================================================================
# scheduler accounting under speculation
# ======================================================================
def test_draft_allowance_caps_at_output_budget():
    heap = SymmetricHeap(("data",), capacity_bytes=1 << 24)
    kv = serve.PagedKVCache(heap, n_layers=1, kv_heads=1, head_dim=4,
                            n_pages=16, page_tokens=4)
    s = serve.FCFSScheduler(kv, max_batch=2, max_seq=32, spec_k=4)
    r = serve.Request(rid=0, prompt=[1, 2], max_new=3)
    s.submit(r)
    s.tick()
    s.note_prefilled(r, 9)                 # out = [9], 2 tokens left
    assert s.draft_allowance(r) == 1       # m - 1 = 1, not spec_k
    r.out.append(8)                        # 1 token left
    assert s.draft_allowance(r) == 0
    r2 = serve.Request(rid=1, prompt=[1], max_new=32 - 1)
    assert r2.is_prefilling() and s.draft_allowance(r2) == 0


def test_spec_budget_claims_verify_window():
    """A decoding sequence claims 1 + allowance tokens, so prefill
    chunks shrink accordingly (decode claims first, oldest prefill
    still guaranteed one token)."""
    heap = SymmetricHeap(("data",), capacity_bytes=1 << 24)
    kv = serve.PagedKVCache(heap, n_layers=1, kv_heads=1, head_dim=4,
                            n_pages=32, page_tokens=4)
    s = serve.FCFSScheduler(kv, max_batch=4, max_seq=64, spec_k=3,
                            prefill_chunk=4, tick_tokens=6)
    r0 = serve.Request(rid=0, prompt=[1, 2], max_new=8)
    s.submit(r0)
    s.tick()
    s.note_prefilled(r0, 9)                # r0 now decoding
    r1 = serve.Request(rid=1, prompt=list(range(10)), max_new=4)
    s.submit(r1)
    plan = s.tick()
    # budget 6 - (1 + 3) for r0's verify window leaves 2 for r1
    assert [(r.rid, n) for r, n in plan.prefill] == [(1, 2)]
    # default budget resolution scales with the window
    s2 = serve.FCFSScheduler(kv, max_batch=4, max_seq=64, spec_k=3,
                             prefill_chunk=4)
    assert s2.tick_tokens == 4 * (1 + 3) + 4
