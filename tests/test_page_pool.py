"""SymmetricPagePool linearizability (paper §4.6 applied to paging).

The lock-free allocator must be indistinguishable from the host LIFO
free list it replaces — ``PagedKVCache.attach_pool`` swaps it in under
the serving stack, so a single page-id divergence moves block tables
and (via placement) token streams.  Three layers of evidence:

  * a property test replays random alloc/free/rollback/grow traces
    against the host-list oracle and demands BIT-IDENTICAL grants for
    every delivery seed (the attach_pool contract);
  * seeded multi-actor interleavings (complete ops shuffled across
    actors, plus issue-level concurrent bump reservations) pin the
    allocator invariants no oracle can state per-trace: no double
    grant, no leak, page conservation;
  * directed tests build the classic lock-free failure modes by hand —
    the ABA interleaving the tag guard exists for, a mid-``pop_page``
    CAS defeat that must retry (not double-grant), empty-pool and
    all-or-nothing rollback boundaries.

Every test also pins the completion discipline: the pool queue drains
AMOs per-word only — ``quiets == fences == 0`` always.
"""
import os
import random
import subprocess
import sys

import numpy as np
import pytest

from repro.serve.page_pool import (W_BUMP, W_NEXT, W_TOP,
                                   _PAGE_MASK, _TAG_SHIFT,
                                   SymmetricPagePool)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ======================================================================
# the host-LIFO oracle (PagedKVCache's free list, verbatim semantics)
# ======================================================================
class HostList:
    """The host free list the pool must be bit-identical to: pages
    ``1..n-1`` popped from the tail, frees ``extend(reversed(...))``."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.free = list(range(n_pages - 1, 0, -1))

    def pop_page(self):
        return self.free.pop() if self.free else None

    def pop_pages(self, n):
        if n > len(self.free):
            return None
        return [self.free.pop() for _ in range(n)]

    def push_pages(self, pages):
        self.free.extend(reversed(list(pages)))

    def n_free(self):
        return len(self.free)

    def grow_pages(self, new_ids):
        ids = sorted(new_ids)
        self.n_pages += len(ids)
        self.free.extend(reversed(ids))


def _zero_quiet(pool):
    qs = pool.queue_stats()
    assert qs["quiets"] == 0 and qs["fences"] == 0, qs
    assert qs["amos"] > 0 and qs["amo_waits"] > 0


# ======================================================================
# property: single-actor traces are bit-identical to the host list
# ======================================================================
def run_trace(rng: random.Random, delivery_seed: int):
    n = rng.randint(4, 12)
    pool = SymmetricPagePool(n, delivery_seed=delivery_seed)
    host = HostList(n)
    held_p, held_h = [], []
    for _ in range(rng.randint(5, 40)):
        op = rng.choices(["pop", "popn", "push", "grow"],
                         weights=[5, 2, 4, 1])[0]
        if op == "pop":
            gp, gh = pool.pop_page(), host.pop_page()
            assert gp == gh, (gp, gh)
            if gp is not None:
                held_p.append(gp)
                held_h.append(gh)
        elif op == "popn":
            k = rng.randint(1, 4)
            gp, gh = pool.pop_pages(k), host.pop_pages(k)
            assert gp == gh, (gp, gh)       # incl. None==None rollback
            if gp is not None:
                held_p.extend(gp)
                held_h.extend(gh)
        elif op == "push" and held_p:
            k = rng.randint(1, len(held_p))
            idx = rng.sample(range(len(held_p)), k)
            back = [held_p[i] for i in idx]
            assert back == [held_h[i] for i in idx]
            pool.push_pages(back)
            host.push_pages(back)
            held_p = [p for i, p in enumerate(held_p) if i not in idx]
            held_h = [p for i, p in enumerate(held_h) if i not in idx]
        elif op == "grow":
            k = rng.randint(1, 3)
            ids = range(pool.n_pages, pool.n_pages + k)
            pool.grow_pages(ids)
            host.grow_pages(ids)
        assert pool.n_free() == host.n_free()
    # drain both dry: every remaining page granted once, same order
    rest_p, rest_h = [], []
    while True:
        gp, gh = pool.pop_page(), host.pop_page()
        assert gp == gh
        if gp is None:
            break
        rest_p.append(gp)
    outstanding = held_p + rest_p
    assert sorted(outstanding) == list(range(1, pool.n_pages))
    assert pool.n_free() == 0
    _zero_quiet(pool)


if HAVE_HYPOTHESIS:
    @settings(max_examples=150, deadline=None)
    @given(st.integers(0, 2 ** 32 - 1), st.integers(0, 35))
    def test_pool_matches_host_lifo_property(seed, dseed):
        run_trace(random.Random(seed), dseed)
else:
    @pytest.mark.parametrize("chunk", range(10))
    def test_pool_matches_host_lifo_property(chunk):
        # 10 chunks x 15 traces, delivery seed swept 0..35 with them
        for i in range(15):
            k = chunk * 15 + i
            run_trace(random.Random(k), k % 36)


# ======================================================================
# multi-actor interleavings: allocator invariants under the shuffle
# ======================================================================
N_ACTORS = 4


def run_concurrent_trace(rng: random.Random, delivery_seed: int):
    n = rng.randint(6, 16)
    pool = SymmetricPagePool(n, n_actors=N_ACTORS,
                             delivery_seed=delivery_seed)
    held = {a: [] for a in range(N_ACTORS)}
    for _ in range(rng.randint(10, 60)):
        a = rng.randrange(N_ACTORS)
        if rng.random() < 0.6:
            p = pool.pop_page(actor=a)
            if p is not None:
                held[a].append(p)
        elif held[a]:
            k = rng.randint(1, len(held[a]))
            back, held[a] = held[a][:k], held[a][k:]
            pool.push_pages(back, actor=a)
        # invariants after EVERY step: grants unique across actors
        # (no double grant), accounting exact (no leak)
        out = [p for ps in held.values() for p in ps]
        assert len(out) == len(set(out)), out
        assert pool.n_free() == (n - 1) - len(out)
    # conservation: return everything, then drain — each page once
    for a, ps in held.items():
        pool.push_pages(ps, actor=a)
    assert pool.n_free() == n - 1
    got = sorted(iter(lambda: pool.pop_page(actor=rng.randrange(N_ACTORS)),
                      None))
    assert got == list(range(1, n))
    _zero_quiet(pool)


if HAVE_HYPOTHESIS:
    @settings(max_examples=120, deadline=None)
    @given(st.integers(0, 2 ** 32 - 1), st.integers(0, 35))
    def test_pool_concurrent_invariants_property(seed, dseed):
        run_concurrent_trace(random.Random(seed), dseed)
else:
    @pytest.mark.parametrize("chunk", range(8))
    def test_pool_concurrent_invariants_property(chunk):
        for i in range(15):
            k = chunk * 15 + i
            run_concurrent_trace(random.Random(5000 + k), k % 36)


def test_concurrent_bump_reservations_grant_unique_pages():
    """Issue-level concurrency: every actor's bump fetch-add is IN
    FLIGHT before any drains — one amo_wait linearizes them all and
    each actor still receives a distinct fresh page, for 30+ shuffle
    seeds (the no-double-grant core of the allocator)."""
    for dseed in list(range(34)) + [None]:
        pool = SymmetricPagePool(2 * N_ACTORS + 1, n_actors=N_ACTORS,
                                 delivery_seed=dseed)
        pend = [pool.amo_issue("fadd", W_BUMP, 1, actor=a)
                for a in range(N_ACTORS)]
        assert not any(r.ready for r in pend)
        pool.amo_drain(W_BUMP)
        ks = [int(r.value()) for r in pend]
        pages = [1 + k for k in ks]
        assert sorted(ks) == list(range(N_ACTORS)), (dseed, ks)
        assert len(set(pages)) == N_ACTORS
        _zero_quiet(pool)


# ======================================================================
# directed: the classic failure modes, built by hand
# ======================================================================
def test_aba_tag_guard_fails_the_stale_cas():
    """The ABA interleaving: actor 1 snapshots TOP (page X) and
    NEXT[X]; actor 0 pops X and pushes it back (same page on top,
    NEW tag).  Actor 1's stale cswap MUST fail — an untagged stack
    would let it through and double-grant X's old next link."""
    pool = SymmetricPagePool(8, n_actors=2)
    a = pool.pop_page(actor=0)
    b = pool.pop_page(actor=0)
    pool.push_pages([a, b], actor=0)       # stack: a -> b
    # actor 1 snapshots the stack head
    top = pool._amo("fetch", W_TOP, actor=1)
    page, tag = top & _PAGE_MASK, top >> _TAG_SHIFT
    assert page == a
    nxt = pool._amo("fetch", W_NEXT + page, actor=1)
    assert nxt == b
    # actor 0 interferes: pop a, pop b, push a back — head shows page a
    # again, but the tag moved
    assert pool.pop_page(actor=0) == a
    assert pool.pop_page(actor=0) == b
    pool.push_pages([a], actor=0)
    top2 = pool._amo("fetch", W_TOP, actor=1)
    assert top2 & _PAGE_MASK == a          # same page value...
    assert top2 != top                     # ...different word: tag moved
    # actor 1 replays its stale pop CAS — the tag must defeat it
    old = pool._amo("cswap", W_TOP, value=((tag + 1) << _TAG_SHIFT) | nxt,
                    cond=top, actor=1)
    assert old != top                      # CAS failed: no ABA pop of b
    # the pool is undamaged: a is still on top, b stays granted
    assert pool.pop_page(actor=1) == a
    assert pool.n_free() == pool.n_pages - 1 - 2    # a + b outstanding
    _zero_quiet(pool)


def test_pop_page_retries_after_cas_defeat():
    """A competing pop lands between ``pop_page``'s TOP snapshot and
    its claim CAS: the loser must RETRY (counted in cas_retries) and
    come back with a different page — never the one the winner took."""
    pool = SymmetricPagePool(8, n_actors=2)
    p1, p2 = pool.pop_page(actor=0), pool.pop_page(actor=0)
    pool.push_pages([p1, p2], actor=0)     # stack: p1 -> p2
    stolen = []
    orig = pool._amo

    def interfere(op, word, value=None, cond=None, *, actor=0):
        # after actor 1 first snapshots TOP, actor 0 races a full pop
        if (op == "fetch" and word == W_TOP and actor == 1
                and not stolen):
            out = orig(op, word, value, cond, actor=actor)
            pool._amo = orig               # interfere exactly once
            stolen.append(pool.pop_page(actor=0))
            return out
        return orig(op, word, value, cond, actor=actor)

    pool._amo = interfere
    got = pool.pop_page(actor=1)
    assert stolen == [p1]                  # the winner took the head
    assert got == p2                       # loser retried onto the next
    assert pool.stats["cas_retries"] >= 1
    outstanding = {p1, p2}
    assert pool.n_free() == pool.n_pages - 1 - len(outstanding)
    _zero_quiet(pool)


def test_empty_pool_and_rollback_boundaries():
    pool = SymmetricPagePool(4)
    host = HostList(4)
    got = [pool.pop_page() for _ in range(3)]
    assert got == [host.pop_page() for _ in range(3)] == [1, 2, 3]
    assert pool.pop_page() is None and pool.n_free() == 0
    assert pool.pop_pages(1) is None
    # all-or-nothing: a shortfall restores the EXACT pre-call state
    pool.push_pages(got[:2])
    host.push_pages(got[:2])
    assert pool.pop_pages(3) is None and pool.n_free() == 2
    assert pool.pop_pages(2) == host.pop_pages(2)
    assert pool.pop_page() is None
    # bump counter stayed conservative through the exhausted probes
    assert pool._amo("fetch", W_BUMP) == 3
    _zero_quiet(pool)


def test_grow_matches_host_growth_order():
    pool = SymmetricPagePool(3)
    host = HostList(3)
    assert pool.pop_pages(2) == host.pop_pages(2) == [1, 2]
    pool.grow_pages(range(3, 6))
    host.grow_pages(range(3, 6))
    assert pool.n_free() == host.n_free() == 3
    got = [pool.pop_page() for _ in range(4)]
    assert got == [host.pop_page() for _ in range(4)]
    assert got == [3, 4, 5, None]
    _zero_quiet(pool)


def test_constructor_and_push_validation():
    with pytest.raises(ValueError, match=">= 2 pages"):
        SymmetricPagePool(1)
    pool = SymmetricPagePool(4)
    with pytest.raises(ValueError, match="outside pool"):
        pool.push_pages([0])               # the null page is never free
    with pytest.raises(ValueError, match="outside pool"):
        pool.push_pages([4])
    p = pool.pop_page()
    pool.push_pages([p])                   # legal ids round-trip
    assert pool.n_free() == 3


def test_attach_pool_is_invisible_to_the_kv_cache():
    """The end-to-end contract: a PagedKVCache driven through an
    attached pool grants the same pages as the host list — tables,
    rollbacks and growth included."""
    from repro.core.heap import SymmetricHeap
    from repro.serve.kv_cache import PagedKVCache

    def make(attach):
        kv = PagedKVCache(SymmetricHeap(("data",)), n_layers=1,
                          kv_heads=1, head_dim=4, n_pages=8,
                          page_tokens=4)
        if attach:
            kv.attach_pool(SymmetricPagePool(kv.n_pages,
                                             name="pool_words_t"))
        return kv

    kvs = [make(False), make(True)]
    for step in (lambda kv: kv.alloc_seq("a", 6),
                 lambda kv: kv.alloc_seq("b", 9),
                 lambda kv: kv.ensure("a", 12),
                 lambda kv: kv.free_seq("b"),
                 lambda kv: kv.take_pages(2),
                 lambda kv: kv.alloc_seq("c", 30),   # must fail both
                 lambda kv: kv.n_free()):
        r0, r1 = step(kvs[0]), step(kvs[1])
        assert r0 == r1, (r0, r1)
    assert kvs[0].tables == kvs[1].tables
    _zero_quiet(kvs[1]._pool)


# ======================================================================
# the multi-PE suite (8 requesters, mesh==queue substrate parity)
# ======================================================================
def test_atomics_8pe():
    if os.environ.get("REPRO_MULTIPE_EXPLICIT"):
        pytest.skip("multipe workers run explicitly (scripts/verify.sh)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "multipe", "run_atomics.py")],
        capture_output=True, text=True, env=env, timeout=2400)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ATOMICS_PASS" in r.stdout
