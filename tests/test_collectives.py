"""Multi-PE collectives/atomics/heap-addressing integration tests.

Run in a SUBPROCESS with 8 fake CPU devices so the main pytest process
keeps a single device (smoke tests and benches must see 1 device).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script):
    if os.environ.get("REPRO_MULTIPE_EXPLICIT"):
        pytest.skip("multipe workers run explicitly (scripts/verify.sh)")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multipe", script)],
        capture_output=True, text=True, env=env, timeout=2400)


def test_core_collectives_8pe():
    r = _run("run_core_checks.py")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "CORE_CHECKS_PASS" in r.stdout


@pytest.mark.slow
def test_dp_tp_equivalence_8pe():
    r = _run("run_tp_equiv.py")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "TP_EQUIV_PASS" in r.stdout


def test_single_pe_degenerate():
    """All collectives are identity on a 1-PE team (in-process)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro import core as posh

    mesh = compat.make_mesh((1,), ("pe",))
    x = jnp.arange(6.0).reshape(1, 6)

    def f(x):
        y = posh.allreduce(x, "sum", "pe", "ring")
        y = posh.broadcast(y, 0, "pe", "binomial")
        g = posh.fcollect(y, "pe", "ring")
        return g[0]

    out = compat.shard_map(f, mesh=mesh, in_specs=P("pe"),
                           out_specs=P("pe"), check_vma=False)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


def test_safety_modes():
    from repro.core import safety

    safety.safe_mode(True)
    try:
        with pytest.raises(safety.PoshSafetyError):
            with safety.collective_guard(("pe",), "outer"):
                with safety.collective_guard(("pe",), "inner"):
                    pass
        # disjoint axes are allowed
        with safety.collective_guard(("a",), "one"):
            with safety.collective_guard(("b",), "two"):
                pass
    finally:
        safety.safe_mode(False)


def test_collective_guard_unwinds_on_raise():
    """Regression: a collective that raises must unwind its in_progress
    frame — a poisoned stack would make every later collective on the
    same team fail the nesting check for the life of the thread."""
    from repro.core import safety

    safety.safe_mode(True)
    try:
        with pytest.raises(RuntimeError, match="boom"):
            with safety.collective_guard(("pe",), "exploder"):
                raise RuntimeError("boom")
        # the stack is clean: the same team is immediately usable again
        with safety.collective_guard(("pe",), "after"):
            pass
        # same through the nesting-violation path: the OUTER frame must
        # survive the inner guard's refusal, and be gone afterwards
        with pytest.raises(safety.PoshSafetyError):
            with safety.collective_guard(("pe",), "outer"):
                with safety.collective_guard(("pe",), "inner"):
                    pass
        with safety.collective_guard(("pe",), "clean"):
            pass
        assert safety._flags().in_progress == []
    finally:
        safety.safe_mode(False)


def test_schedule_validation():
    from repro.core.p2p import _check_pairs

    with pytest.raises(ValueError):
        _check_pairs([(0, 1), (0, 2)], 4, "t")   # duplicate source
    with pytest.raises(ValueError):
        _check_pairs([(0, 9)], 4, "t")           # out of range
    assert _check_pairs([(0, 1), (1, 0)], 2, "t") == [(0, 1), (1, 0)]
