"""Serving quickstart: a 3-request continuous-batching decode trace
over the paged symmetric-heap KV cache.

    PYTHONPATH=src python examples/serve_quickstart.py

What to look for in the output:

  * tick 1 admits all three requests FCFS and starts CHUNKED prefill —
    each prompt's K/V lands in fixed-size PAGES carved from the
    symmetric heap, and each request's cache is a BLOCK TABLE of page
    ids (printed per request).  Page ids are symmetric addresses: the
    same table is valid on every PE (POSH Fact 1 at page granularity),
    which is what makes cross-PE page migration a one-sided ``put_nbi``
    (see tests/multipe/run_serve.py for the 8-PE version).
  * prefill is TOKEN-BUDGETED: each tick hands every prefilling
    request up to ``prefill_chunk`` prompt tokens from a budget shared
    with decode (decode claims first), so watch the ``prefill i/n``
    counters advance a chunk per tick instead of one prompt
    monopolizing the tick.
  * every later tick decodes ONE token for EVERY decoding request in a
    single batched step — requests of different lengths share the batch
    (continuous batching), and a request that finishes frees its pages
    for the next admission.
  * the decode step's attention reads K/V *through the block table*
    (``ops.paged_attention`` — Pallas kernel on TPU, jnp gather here),
    and every step ends in the TP-aware sampler (greedy here; pass
    ``serve.SamplingParams(temperature=..., top_p=...)`` on a Request
    for top-k/p sampling with per-request RNG streams).
"""
import jax
import jax.numpy as jnp

from repro import configs, serve
from repro.models import registry
from repro.parallel.ctx import ParallelCtx


def main():
    cfg = configs.get_smoke("qwen3-8b")
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)

    scfg = serve.ServeConfig(page_tokens=4, n_pages=16, max_batch=3,
                             max_seq=32, max_prompt=16, attn_impl="ref")
    eng = serve.ServeEngine(params, cfg, ctx, scfg)

    reqs = [serve.Request(rid=0, prompt=[11, 12, 13, 14, 15, 16], max_new=5),
            serve.Request(rid=1, prompt=[50, 51, 52], max_new=7),
            serve.Request(rid=2, prompt=[90, 91, 92, 93, 94, 95, 96, 97],
                          max_new=3)]
    for r in reqs:
        eng.submit(r)

    print(f"pool: {scfg.n_pages} pages x {scfg.page_tokens} tokens "
          f"(page 0 = null), {cfg.n_layers} layers")
    while eng.sched.has_work():
        eng.tick(now=float(eng.ticks))
        running = {r.rid: (f"prefill {r.n_done}/{r.n_prompt}"
                           if r.is_prefilling()
                           else f"decode {len(r.out)}/{r.max_new}")
                   for r in eng.sched.running}
        tables = {rid: eng.kv.tables[rid] for rid in
                  (r.rid for r in eng.sched.running)}
        print(f"tick {eng.ticks}: running={running} "
              f"block_tables={tables} free_pages={eng.kv.n_free()}")

    print("\ndecoded streams (greedy):")
    for r in sorted(eng.finished, key=lambda r: r.rid):
        print(f"  req{r.rid}: prompt={r.prompt} -> {r.out}")
    m = eng.metrics()
    print(f"\n{m['tokens_out']} tokens over {m['ticks']} ticks; "
          f"scheduler: {m['sched']}")


if __name__ == "__main__":
    main()
