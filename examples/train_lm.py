"""End-to-end training driver: a ~100M-param decoder LM trained for a
few hundred steps with checkpointing, restart safety, and the POSH
collective backend.

    PYTHONPATH=src python examples/train_lm.py --preset small --steps 200

Presets: small (~10M, CPU-friendly: a few minutes), 100m (~100M — the
deliverable configuration; sized for a real accelerator, runs on CPU
but slowly).  Loss on the synthetic bigram corpus falls well below the
uniform baseline within a few hundred steps.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.ckpt import Checkpointer
from repro.configs.base import ArchConfig
from repro.data import SyntheticLM
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step, train_state_specs

PRESETS = {
    "small": ArchConfig(name="lm-small", family="dense", n_layers=4,
                        d_model=256, n_heads=4, n_kv=2, head_dim=64,
                        d_ff=768, vocab=2048, act="swiglu", max_seq=128),
    "100m": ArchConfig(name="lm-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv=4, head_dim=64,
                       d_ff=2304, vocab=32768, act="swiglu", max_seq=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--backend", default="posh", choices=["posh", "xla"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=True,
                      backend=args.backend,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    opt = AdamWConfig(lr=6e-4, weight_decay=0.01)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    sspecs = train_state_specs(cfg, ctx, api, opt)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    n_params = sum(l.size for l in jax.tree.leaves(params))
    opt_state = smap(lambda p: adamw_init(p, ctx, opt), mesh,
                     (api.specs(cfg, ctx),), sspecs["opt"])(params)
    state = {"params": params, "opt": opt_state,
             "step": jnp.zeros((), jnp.int32)}
    ck = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    if args.resume:
        state, start = ck.restore(state)
        print(f"resumed from step {start}")

    fn = jax.jit(smap(make_train_step(cfg, ctx, api, opt), mesh,
                      (sspecs, {"tokens": P("data")}),
                      (sspecs, {"loss": P(), "grad_norm": P(),
                                "step": P()})))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=cfg.max_seq,
                       global_batch=args.batch)
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, backend={args.backend}, "
          f"uniform-baseline loss={jnp.log(cfg.vocab):.3f}")
    t0 = time.time()
    for s in range(start, args.steps):
        state, m = fn(state, data.batch(s))
        if s % 10 == 0 or s == args.steps - 1:
            toks = args.batch * cfg.max_seq
            dt = (time.time() - t0) / max(s - start + 1, 1)
            print(f"step {s:4d}  loss {float(m['loss']):.4f}  "
                  f"{toks/dt:,.0f} tok/s")
        if (s + 1) % args.ckpt_every == 0:
            ck.save_async(s + 1, state)
    ck.wait()
    print("done.")


if __name__ == "__main__":
    main()
