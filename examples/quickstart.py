"""Quickstart: train a tiny LM through the POSH communication layer.

    PYTHONPATH=src python examples/quickstart.py

Runs ~40 steps on CPU in about a minute and prints a decreasing loss.
Every collective in the step (TP completion, DP mean — degenerate at
1 device but the code path is identical) goes through a first-class
``Communicator`` bound to each mesh axis, with the paper's put/get-based
schedules when --backend posh.

MIGRATION NOTE (free functions -> Communicator methods)
-------------------------------------------------------
The pre-PR-1 API was free functions taking an axis and a run-wide
``CommConfig``; those shims are now REMOVED (deprecated in PR 1,
deleted on schedule two PRs after the ordered pipeline).  The API binds
the team once and dispatches the algorithm per call from payload size
and team size (POSH §4.5.4)::

    tp = comm.make_communicator("model", size=8, backend="posh")
    y = tp.psum(x)                   # small x -> tree, large x -> ring
    g = tp.all_gather(x, axis=1)
    tp.stats()                       # {"psum": {"calls", "bytes", "algos"}}

Model code gets the communicators from the parallel context, built once
from the mesh: ``ctx.tp_comm`` / ``ctx.dp_comm`` (construct the ctx
with ``backend="posh"`` — or ``ParallelCtx.from_mesh(mesh, ...)``).
The old fixed-algorithm behaviour is ``DispatchTable.fixed(...)``; a
bare axis name is still accepted by ``comm.as_communicator`` and the
tree reductions inside shard_map.
"""
import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.data import SyntheticLM
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step, train_state_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b",
                    help="architecture id (smoke-size config is used)")
    ap.add_argument("--backend", default="posh", choices=["posh", "xla"])
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    ctx = ParallelCtx.from_mesh(mesh, sp=False, remat=True,
                                backend=args.backend,
                                param_dtype=jnp.float32,
                                compute_dtype=jnp.float32)
    api = registry.build(cfg)
    opt = AdamWConfig(lr=1e-3)
    sspecs = train_state_specs(cfg, ctx, api, opt)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    opt_state = smap(lambda p: adamw_init(p, ctx, opt), mesh,
                     (api.specs(cfg, ctx),), sspecs["opt"])(params)
    state = {"params": params, "opt": opt_state,
             "step": jnp.zeros((), jnp.int32)}
    fn = jax.jit(smap(make_train_step(cfg, ctx, api, opt), mesh,
                      (sspecs, {"tokens": P("data")}),
                      (sspecs, {"loss": P(), "grad_norm": P(),
                                "step": P()})))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=cfg.max_seq, global_batch=8)
    print(f"arch={cfg.name} backend={args.backend} "
          f"params={sum(l.size for l in jax.tree.leaves(params)):,}")
    for s in range(args.steps):
        state, m = fn(state, data.batch(s))
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss {float(m['loss']):.4f}  "
                  f"|g| {float(m['grad_norm']):.3f}")
    # what the communicators did (trace-time op accounting)
    for name, c in [("tp", ctx.tp_comm), ("dp", ctx.dp_comm)]:
        print(f"{name}_comm stats: {c.stats()}")


if __name__ == "__main__":
    main()
