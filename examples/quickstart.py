"""Quickstart: train a tiny LM through the POSH communication layer.

    PYTHONPATH=src python examples/quickstart.py

Runs ~40 steps on CPU in about a minute and prints a decreasing loss.
Every collective in the step (TP completion, DP mean — degenerate at
1 device but the code path is identical) goes through repro.comm with
the paper's put/get-based schedules when --backend posh.
"""
import argparse

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import comm, configs
from repro.data import SyntheticLM
from repro.models import registry
from repro.parallel.ctx import ParallelCtx, smap
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import make_train_step, train_state_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b",
                    help="architecture id (smoke-size config is used)")
    ap.add_argument("--backend", default="posh", choices=["posh", "xla"])
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=True,
                      comm=comm.CommConfig(backend=args.backend),
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    opt = AdamWConfig(lr=1e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sspecs = train_state_specs(cfg, ctx, api, opt)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    opt_state = jax.shard_map(lambda p: adamw_init(p, ctx, opt), mesh=mesh,
                              in_specs=(api.specs(cfg, ctx),),
                              out_specs=sspecs["opt"],
                              check_vma=False)(params)
    state = {"params": params, "opt": opt_state,
             "step": jnp.zeros((), jnp.int32)}
    fn = jax.jit(smap(make_train_step(cfg, ctx, api, opt), mesh,
                      (sspecs, {"tokens": P("data")}),
                      (sspecs, {"loss": P(), "grad_norm": P(),
                                "step": P()})))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=cfg.max_seq, global_batch=8)
    print(f"arch={cfg.name} backend={args.backend} "
          f"params={sum(l.size for l in jax.tree.leaves(params)):,}")
    for s in range(args.steps):
        state, m = fn(state, data.batch(s))
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss {float(m['loss']):.4f}  "
                  f"|g| {float(m['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
