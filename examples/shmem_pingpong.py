"""The paper's own experiment: put/get latency & bandwidth through the
POSH layer vs a local copy (Tables 1–2), on 8 simulated PEs.

    PYTHONPATH=src python examples/shmem_pingpong.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import core as posh


def main():
    mesh = compat.make_mesh((8,), ("pe",))

    def smap(fn):
        return compat.shard_map(fn, mesh=mesh, in_specs=P("pe"),
                                out_specs=P("pe"), check_vma=False)

    print(f"{'elems/PE':>10} {'put us':>9} {'get us':>9} {'copy us':>9} "
          f"{'put GB/s':>9}")
    for elems in [64, 1024, 16384, 262144, 1048576]:
        x = jnp.arange(8 * elems, dtype=jnp.float32).reshape(8, elems)
        put = jax.jit(smap(lambda v: posh.ring_shift(v, "pe", 1)))
        get = jax.jit(smap(lambda v: posh.get(
            v, [((i + 1) % 8, i) for i in range(8)], "pe")))
        cpy = jax.jit(smap(lambda v: v * 1))

        def t(fn):
            for _ in range(3):
                jax.block_until_ready(fn(x))
            t0 = time.perf_counter()
            for _ in range(20):
                out = fn(x)
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / 20

        tp, tg, tc = t(put), t(get), t(cpy)
        print(f"{elems:>10} {tp*1e6:>9.1f} {tg*1e6:>9.1f} {tc*1e6:>9.1f} "
              f"{elems*4/tp/1e9:>9.3f}")
    print("\npaper claim (§5.2): put/get ≈ local copy — overhead should be"
          " small and size-independent at large buffers.")


if __name__ == "__main__":
    main()
