"""The paper's own experiment: put/get latency & bandwidth through the
POSH layer vs a local copy (Tables 1–2), on 8 simulated PEs — plus the
nonblocking pipeline: N puts issued ``put_nbi`` and drained by one
``quiet()`` vs N blocking rounds (§3.2 overlap).

    PYTHONPATH=src python examples/shmem_pingpong.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import core as posh


def main():
    mesh = compat.make_mesh((8,), ("pe",))

    def smap(fn):
        return compat.shard_map(fn, mesh=mesh, in_specs=P("pe"),
                                out_specs=P("pe"), check_vma=False)

    print(f"{'elems/PE':>10} {'put us':>9} {'get us':>9} {'copy us':>9} "
          f"{'put GB/s':>9}")
    for elems in [64, 1024, 16384, 262144, 1048576]:
        x = jnp.arange(8 * elems, dtype=jnp.float32).reshape(8, elems)
        put = jax.jit(smap(lambda v: posh.ring_shift(v, "pe", 1)))
        get = jax.jit(smap(lambda v: posh.get(
            v, [((i + 1) % 8, i) for i in range(8)], "pe")))
        cpy = jax.jit(smap(lambda v: v * 1))

        def t(fn):
            for _ in range(3):
                jax.block_until_ready(fn(x))
            t0 = time.perf_counter()
            for _ in range(20):
                out = fn(x)
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / 20

        tp, tg, tc = t(put), t(get), t(cpy)
        print(f"{elems:>10} {tp*1e6:>9.1f} {tg*1e6:>9.1f} {tc*1e6:>9.1f} "
              f"{elems*4/tp/1e9:>9.3f}")
    print("\npaper claim (§5.2): put/get ≈ local copy — overhead should be"
          " small and size-independent at large buffers.")

    # --- the §3.2 pipeline: K nbi puts, one quiet ---------------------
    heap = posh.SymmetricHeap(("pe",))
    K, elems = 8, 16384
    h = heap.alloc("pipe", (K * elems,), jnp.float32)
    pairs = [(i, (i + 1) % 8) for i in range(8)]

    def nbi(v):
        q = posh.CommQueue("pe", {"pipe": jnp.zeros((K * elems,),
                                                    jnp.float32)})
        for k in range(K):          # all pending, mutually independent
            posh.put_nbi(q, h, v[0, k * elems:(k + 1) * elems], pairs,
                         offset=k * elems)
        return posh.quiet(q)["pipe"][None]      # ONE completion barrier

    def blocking(v):
        st = {"pipe": jnp.zeros((K * elems,), jnp.float32)}
        for k in range(K):          # each round fully ordered
            st = posh.heap_put(st, h, v[0, k * elems:(k + 1) * elems],
                               pairs, "pe", offset=k * elems)
        return st["pipe"][None]

    big = jnp.arange(8 * K * elems, dtype=jnp.float32).reshape(8, K * elems)
    smap2 = lambda fn: jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=P("pe"), out_specs=P("pe", None),
        check_vma=False))
    for name, fn in (("nbi+quiet", smap2(nbi)), ("blocking", smap2(blocking))):
        for _ in range(3):
            jax.block_until_ready(fn(big))
        t0 = time.perf_counter()
        for _ in range(20):
            out = fn(big)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 20
        print(f"{K} x {elems} puts via {name:<10}: {dt*1e6:9.1f} us")
    print("nbi issues all rounds before the single drain — XLA may "
          "schedule them concurrently; blocking serializes each round.")


if __name__ == "__main__":
    main()
