"""Serving example: prefill a batch of prompts, then greedy-decode with
the sharded KV cache (ring cache under sliding-window configs).

    PYTHONPATH=src python examples/serve_decode.py --arch h2o-danube-3-4b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import registry
from repro.parallel.ctx import ParallelCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=False,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    api = registry.build(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)

    b = args.batch
    state = api.init_decode_state(cfg, ctx, b, max_len=64)
    step = jax.jit(lambda p, t, s: api.decode_step(p, t, s, ctx, cfg))

    tok = jax.random.randint(jax.random.PRNGKey(1), (b,), 0, cfg.vocab)
    outs = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens):
        tok, state = step(params, tok, state)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seqs = jnp.stack(outs, axis=1)
    print(f"{cfg.name}: decoded {args.new_tokens} tokens x {b} requests "
          f"in {dt:.2f}s ({b*args.new_tokens/dt:.1f} tok/s)")
    for i in range(min(b, 2)):
        print(f"  req{i}: {list(map(int, seqs[i][:12]))}...")


if __name__ == "__main__":
    main()
