"""Inject generated tables + §Perf log into EXPERIMENTS.md."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "benchmarks"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.build_experiments import (load, multipod_table,
                                          roofline_table)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def perf_log():
    rows = load("hillclimb.jsonl")
    by_variant = {r.get("variant"): r for r in rows if "compute_ms" in r}
    # minitron ctx-layout baseline comes from the sweep table
    if "baseline_ctx_layout" not in by_variant:
        for r in load("dryrun_single.jsonl"):
            if r.get("arch") == "minitron-4b" and r.get("shape") == "train_4k" \
                    and r.get("status") == "ok":
                by_variant["baseline_ctx_layout"] = r
    if "baseline_einsum_dispatch" not in by_variant:
        for r in load("dryrun_single.jsonl"):
            if r.get("arch") == "qwen2-moe-a2.7b" and \
                    r.get("shape") == "train_4k" and r.get("status") == "ok":
                by_variant["baseline_einsum_dispatch"] = r

    def t(v, k):
        r = by_variant.get(v)
        return f"{r[k]:.0f}" if r else "–"

    out = []
    out.append("""### Cell 2 — qwen3-8b × train_4k (collective-bound)

| iteration | hypothesis | compute ms | HBM ms | ICI ms | verdict |
|---|---|---|---|---|---|
| 0 (paper-faithful) | posh ring RS+AG schedules for every collective — the reproduction baseline | """
               + t("baseline_posh_ring_zero1", "compute_ms") + " | "
               + t("baseline_posh_ring_zero1", "memory_ms") + " | "
               + t("baseline_posh_ring_zero1", "collective_ms")
               + """ | baseline |
| 1 (beyond-paper) | napkin: ring decomposition moves 2(n−1)/n·B in 30 explicit permute rounds whose chunk buffers all transit HBM; native fused all-reduce should cut ICI bytes ~1.6× and remove the round-trip HBM traffic entirely → switch backend posh→xla | """
               + t("xla_collectives_zero1", "compute_ms") + " | "
               + t("xla_collectives_zero1", "memory_ms") + " | "
               + t("xla_collectives_zero1", "collective_ms")
               + """ | **confirmed**: ICI 1.6×↓, HBM 5.9×↓ — the paper's software schedules are the right *portability* layer but native collectives are the perf ceiling; both kept selectable |
| 2 | ZeRO-1 (RS grads + AG params) should cut collective volume vs ZeRO-0 psum | """
               + t("xla_collectives_zero0", "compute_ms") + " | "
               + t("xla_collectives_zero0", "memory_ms") + " | "
               + t("xla_collectives_zero0", "collective_ms")
               + """ | **refuted**: RS+AG ≡ psum in volume (expected in hindsight: ring psum = RS+AG).  ZeRO-1's win is optimizer-state *memory* (×dp less), not wire bytes — kept for the fit, not the speed |

Post-hillclimb dominant term: HBM (XLA:CPU fusion caveat, EXPERIMENTS
§caveats); achieved compute/dominant ratio = """
               + (f"{by_variant['xla_collectives_zero1']['compute_ms'] / by_variant['xla_collectives_zero1']['memory_ms']:.2f}"
                  if "xla_collectives_zero1" in by_variant else "–")
               + """ vs baseline """
               + (f"{by_variant['baseline_posh_ring_zero1']['compute_ms'] / by_variant['baseline_posh_ring_zero1']['memory_ms']:.2f}"
                  if "baseline_posh_ring_zero1" in by_variant else "–") + ".\n")

    if "padded_heads_32_head_layout" in by_variant:
        r = by_variant["padded_heads_32_head_layout"]
        b = by_variant.get("baseline_ctx_layout")
        brow = (f"| 0 (baseline) | ctx-layout attention (24 heads ∤ TP=16): "
                f"attention weights replicated per device | "
                f"{b['compute_ms']:.0f} | {b['memory_ms']:.0f} | "
                f"{b['collective_ms']:.0f} | baseline |\n") if b else ""
        out.append(f"""### Cell 1 — minitron-4b × train_4k (worst roofline fraction)

| iteration | hypothesis | compute ms | HBM ms | ICI ms | verdict |
|---|---|---|---|---|---|
{brow}| 1 (beyond-paper) | pad 24→32 query heads (zero-padded heads are function-preserving) ⇒ head-parallel layout, attention weights TP-sharded; predicted: HBM term down by the replicated-weight traffic share, compute up ≈ attention-share × 33% | {r['compute_ms']:.0f} | {r['memory_ms']:.0f} | {r['collective_ms']:.0f} | see terms — padding also moves the per-device peak below HBM (head-sharded grads) |
""")
    for v, title in [("baseline_einsum_dispatch",
                      "einsum dispatch + psum combine (baseline)"),
                     ("posh_alltoall_dispatch",
                      "posh pairwise alltoall dispatch"),
                     ("xla_alltoall_dispatch", "native alltoall dispatch"),
                     ("danube_gathered", "gathered (naive) CE on danube")]:
        if v in by_variant:
            r = by_variant[v]
            out.append(f"- **{title}** ({r['arch']} × {r['shape']}): "
                       f"compute {r['compute_ms']:.0f} / HBM "
                       f"{r['memory_ms']:.0f} / ICI {r['collective_ms']:.0f} ms "
                       f"(dominant: {r['dominant']})")
    out.append("""
### Cell 3 — qwen2-moe-a2.7b × train_4k (paper-representative)

The MoE dispatch is the paper's §4.5 thesis made load-bearing: expert
routing traffic travels over a collective BUILT FROM one-sided put
rounds (pairwise-exchange alltoall).  einsum dispatch (baseline row in
§Roofline) computes routing redundantly on every TP rank and pays one
psum of (tokens × d_model); alltoall dispatch moves only the routed
tokens (k/tp of the einsum volume at top-4/TP-16).  Numbers above;
both modes verified bit-equivalent in gradients
(tests/multipe/run_tp_equiv.py).
""")
    return "\n".join(out)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    single = load("dryrun_single.jsonl")
    multi = load("dryrun_multi.jsonl")
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table(single))
    text = text.replace("<!-- MULTIPOD_TABLE -->", multipod_table(multi))
    text = text.replace("<!-- PERF_LOG -->", perf_log())
    open(path, "w").write(text)
    print(f"EXPERIMENTS.md updated: {len(single)} single-pod rows, "
          f"{len(multi)} multi-pod rows")


if __name__ == "__main__":
    main()
