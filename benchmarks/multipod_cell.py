"""One multi-pod dry-run cell (compile-proof, rolled scans)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys

sys.path.insert(0, "src")
from repro.launch.dryrun import lower_cell

res = lower_cell(sys.argv[1], sys.argv[2], multi_pod=True, backend="posh",
                 unroll=False, verbose=False)
print(json.dumps({k: v for k, v in res.items() if k != "coll_counts"}))
