"""Multi-PE benchmark worker — run as a subprocess with 8 fake devices.

Covers the paper's measurements:
  Table 2: put/get latency/bandwidth through the POSH layer vs a local
           device copy (the 'memcpy' baseline)
  Table 3: POSH collectives vs native XLA collectives (the UPC/GASNet
           role) across buffer sizes
  §4.5.4:  collective algorithm selection (ring / tree / rec-doubling)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import core as posh

mesh = compat.make_mesh((8,), ("pe",))
N = 8
REPEATS = 20   # paper: 20 reps after warm-up
WARMUP = 3


def smap(fn, out_specs=P("pe")):
    return compat.shard_map(fn, mesh=mesh, in_specs=P("pe"),
                            out_specs=out_specs, check_vma=False)


def timeit(fn, x):
    for _ in range(WARMUP):
        jax.block_until_ready(fn(x))
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        out = fn(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPEATS


def bench_p2p():
    print("table,op,elems_per_pe,us_per_call,gbps")
    for elems in [256, 4096, 65536, 1048576]:
        x = jnp.arange(N * elems, dtype=jnp.float32).reshape(N, elems)
        bytes_moved = elems * 4

        put_fn = jax.jit(smap(lambda v: posh.ring_shift(v, "pe", 1)))
        get_fn = jax.jit(smap(lambda v: posh.get(
            v, [((i + 1) % N, i) for i in range(N)], "pe")))
        copy_fn = jax.jit(smap(lambda v: v * 1))  # local 'memcpy' baseline

        for name, fn in [("put", put_fn), ("get", get_fn),
                         ("local_copy", copy_fn)]:
            dt = timeit(fn, x)
            print(f"table2,{name},{elems},{dt*1e6:.2f},"
                  f"{bytes_moved/dt/1e9:.3f}")


def bench_collectives():
    for elems in [1024, 65536, 1048576]:
        x = jnp.arange(N * elems, dtype=jnp.float32).reshape(N, elems)
        cases = [
            ("allreduce_posh_ring",
             lambda v: posh.allreduce(v, "sum", "pe", "ring")),
            ("allreduce_posh_tree",
             lambda v: posh.allreduce(v, "sum", "pe", "tree")),
            ("allreduce_posh_rd",
             lambda v: posh.allreduce(v, "sum", "pe", "recursive_doubling")),
            ("allreduce_xla",
             lambda v: posh.allreduce(v, "sum", "pe", "xla")),
            ("bcast_posh_binomial",
             lambda v: posh.broadcast(v, 0, "pe", "binomial")),
            ("bcast_posh_linear",
             lambda v: posh.broadcast(v, 0, "pe", "linear")),
            ("bcast_xla", lambda v: posh.broadcast(v, 0, "pe", "xla")),
        ]
        for name, body in cases:
            fn = jax.jit(smap(body))
            dt = timeit(fn, x)
            print(f"table3,{name},{elems},{dt*1e6:.2f},"
                  f"{elems*4/dt/1e9:.3f}")
        ag_cases = [
            ("allgather_posh_ring",
             lambda v: posh.fcollect(v, "pe", "ring")),
            ("allgather_posh_rd",
             lambda v: posh.fcollect(v, "pe", "recursive_doubling")),
            ("allgather_xla", lambda v: posh.fcollect(v, "pe", "xla")),
        ]
        for name, body in ag_cases:
            fn = jax.jit(smap(body, out_specs=P("pe", None)))
            dt = timeit(fn, x)
            print(f"table3,{name},{elems},{dt*1e6:.2f},"
                  f"{elems*4*(N-1)/dt/1e9:.3f}")


def bench_atomics():
    heap = posh.SymmetricHeap(("pe",))
    h = heap.alloc("cells", (8,), jnp.float32)

    def fadd(v):
        st = {"cells": jnp.zeros((8,), jnp.float32)}
        st, old = posh.atomic_fadd(st, h, 0, v[0], "pe", owner=0)
        return old[None]

    fn = jax.jit(smap(fadd))
    x = jnp.ones((8, 1), jnp.float32)
    dt = timeit(fn, x)
    print(f"atomics,fadd_owner_computes,1,{dt*1e6:.2f},0")


if __name__ == "__main__":
    bench_p2p()
    bench_collectives()
    bench_atomics()
    print("WORKER_DONE")
