"""Communicator microbenchmark — the numbers behind the dispatch table.

Sweeps message sizes per collective op across the available algorithms
(posh eager, posh chunked, native xla) on 8 fake CPU PEs and writes
``BENCH_comm.json`` at the REPO ROOT (the bench trajectory the driver
tracks):

    {"meta": {...},
     "results": [{"op", "algo", "nbytes", "elems", "us_per_call",
                  "bytes_per_s"}, ...],
     "chosen": [{"op", "nbytes", "algo"}, ...],          # dispatch table
     "tuned_thresholds": {"allreduce_small_bytes": ...}} # measured

Beyond the schedule sweep it also covers the transport matrix:

  * backend rows (``algo = "backend:<name>"``): the same collective
    issued through each registered Communicator backend — xla, posh,
    and the Pallas symm_copy transport — so backend overhead is a
    measured quantity, not folklore;
  * copy-engine rows (``op = "symm_copy"``, ``algo = <variant>``): the
    §4.4 memcpy-variant sweep (stock / auto / each VMEM tiling).

``DispatchTable``'s default thresholds cite this file: re-run after
touching the schedules and feed the result back with
``DispatchTable.tuned_from_bench(json.load(open("BENCH_comm.json")))``.

    PYTHONPATH=src python benchmarks/comm_microbench.py [--quick]

The sweep re-execs itself in a subprocess so the parent process (and
any test harness importing this module) never locks jax to 8 devices.
On CPU the Pallas rows run the interpreter, so backend/copy sweeps are
capped at 64 KiB — interpret timings measure the staging structure, not
kernel throughput (meta records the cap).
"""
import argparse
import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
OUT = os.path.join(ROOT, "BENCH_comm.json")

SIZES_FULL = [256, 4096, 65536, 1048576]       # bytes per PE
SIZES_QUICK = [4096, 262144]
PALLAS_CAP = 65536        # interpret-mode ceiling for backend/copy rows

N = 8


def _worker(sizes):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import comm as C
    from repro import compat
    from repro import core as posh

    mesh = compat.make_mesh((N,), ("pe",))

    def smap(fn, out_specs=P("pe")):
        return compat.shard_map(fn, mesh=mesh, in_specs=P("pe"),
                                out_specs=out_specs, check_vma=False)

    def timeit(fn, x, warmup=2, reps=10):
        for _ in range(warmup):
            jax.block_until_ready(fn(x))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(x)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps

    # op -> (algos, body(algo), out_specs, wire-bytes factor per PE)
    def ar(algo):
        return lambda v: posh.allreduce(v, "sum", "pe", algo)

    def ag(algo):
        return lambda v: posh.fcollect(v, "pe", algo)

    def rs(algo):
        return lambda v: posh.reduce_scatter(v.reshape(N, -1), "sum",
                                             "pe", algo)

    def a2a(algo):
        return lambda v: posh.alltoall(v.reshape(N, -1), "pe", algo)

    def bc(algo):
        return lambda v: posh.broadcast(v, 0, "pe", algo)

    OPS = [
        ("psum", ["tree", "recursive_doubling", "ring", "xla"], ar, P("pe")),
        ("all_gather", ["recursive_doubling", "ring", "xla"], ag,
         P("pe", None)),
        ("psum_scatter", ["ring", "xla"], rs, P("pe")),
        ("all_to_all", ["pairwise", "xla"], a2a, P("pe", None)),
        ("pbroadcast", ["binomial", "linear", "xla"], bc, P("pe")),
    ]

    results = []
    for op, algos, mkbody, ospec in OPS:
        for nbytes in sizes:
            elems = max(nbytes // 4, N)
            elems = (elems // N) * N or N           # divisible for rs/a2a
            x = jnp.arange(N * elems, dtype=jnp.float32).reshape(N, elems)
            for algo in algos:
                fn = jax.jit(smap(mkbody(algo), out_specs=ospec))
                dt = timeit(fn, x)
                row = {"op": op, "algo": algo, "nbytes": elems * 4,
                       "elems": elems, "us_per_call": round(dt * 1e6, 2),
                       "bytes_per_s": round(elems * 4 / dt, 0)}
                results.append(row)
                print(f"  {op:<13} {algo:<19} {elems*4:>9}B "
                      f"{dt*1e6:>10.1f}us", flush=True)

    # --- transport matrix: each registered backend on the hot ops ----
    backend_sizes = [nb for nb in sizes if nb <= PALLAS_CAP] or [sizes[0]]
    for backend in C.available_backends():
        comm = C.make_communicator("pe", size=N, backend=backend)
        bodies = {
            "psum": (lambda v: comm.psum(v), P("pe")),
            "all_gather": (lambda v: comm.all_gather(v, axis=0),
                           P("pe", None)),
            "psum_scatter": (lambda v: comm.psum_scatter(
                v.reshape(N, -1), axis=0), P("pe")),
        }
        for op, (body, ospec) in bodies.items():
            for nbytes in backend_sizes:
                elems = max(nbytes // 4, N)
                elems = (elems // N) * N or N
                x = jnp.arange(N * elems, dtype=jnp.float32).reshape(N, elems)
                fn = jax.jit(smap(body, out_specs=ospec))
                dt = timeit(fn, x)
                results.append(
                    {"op": op, "algo": f"backend:{backend}",
                     "nbytes": elems * 4, "elems": elems,
                     "us_per_call": round(dt * 1e6, 2),
                     "bytes_per_s": round(elems * 4 / dt, 0)})
                print(f"  {op:<13} backend:{backend:<11} {elems*4:>9}B "
                      f"{dt*1e6:>10.1f}us", flush=True)

    # --- the §4.4 copy-engine variant sweep (single device) ----------
    from repro.kernels import ops as kops
    copy_sizes = [nb for nb in sizes if nb <= PALLAS_CAP] or [sizes[0]]
    for variant in kops.COPY_VARIANTS:
        for nbytes in copy_sizes:
            elems = max(nbytes // 4, 8)
            x = jnp.arange(elems, dtype=jnp.float32)
            fn = lambda v: kops.symm_copy(v, variant)
            dt = timeit(fn, x)
            results.append(
                {"op": "symm_copy", "algo": variant, "nbytes": elems * 4,
                 "elems": elems, "us_per_call": round(dt * 1e6, 2),
                 "bytes_per_s": round(elems * 4 / dt, 0)})
            print(f"  {'symm_copy':<13} {variant:<19} {elems*4:>9}B "
                  f"{dt*1e6:>10.1f}us", flush=True)

    # what the default dispatch table picks at each size
    table = C.DispatchTable()
    chosen = [{"op": op, "nbytes": nb, "algo": table.choose(op, nb, N)}
              for op in ("psum", "all_gather", "psum_scatter", "all_to_all",
                         "pbroadcast")
              for nb in sizes]

    bench = {"results": results, "chosen": chosen}
    tuned = C.DispatchTable.tuned_from_bench(bench)
    bench["tuned_thresholds"] = {
        "allreduce_small_bytes": tuned.allreduce_small_bytes,
        "allgather_small_bytes": tuned.allgather_small_bytes,
    }
    bench["meta"] = {"n_pe": N, "device": "cpu-sim",
                     "backends": list(C.available_backends()),
                     "copy_variants": list(kops.COPY_VARIANTS),
                     "pallas_interpret_cap_bytes": PALLAS_CAP,
                     "defaults": {
                         "allreduce_small_bytes":
                             C.DispatchTable().allreduce_small_bytes,
                         "allgather_small_bytes":
                             C.DispatchTable().allgather_small_bytes}}
    print("WORKER_JSON_BEGIN")
    print(json.dumps(bench))
    print("WORKER_JSON_END")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 sizes instead of 4 (fast CI sweep)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()
    sizes = SIZES_QUICK if args.quick else SIZES_FULL

    if args.worker:
        _worker(sizes)
        return

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    cmd = [sys.executable, os.path.abspath(__file__), "--worker"]
    if args.quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=3600)
    sys.stdout.write(r.stdout[:r.stdout.find("WORKER_JSON_BEGIN")]
                     if "WORKER_JSON_BEGIN" in r.stdout else r.stdout)
    if r.returncode != 0 or "WORKER_JSON_END" not in r.stdout:
        print("comm microbench worker FAILED", file=sys.stderr)
        print(r.stdout[-3000:], file=sys.stderr)
        print(r.stderr[-3000:], file=sys.stderr)
        raise SystemExit(1)
    payload = r.stdout.split("WORKER_JSON_BEGIN")[1] \
                      .split("WORKER_JSON_END")[0].strip()
    bench = json.loads(payload)
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"wrote {args.out}: {len(bench['results'])} rows; measured "
          f"thresholds {bench['tuned_thresholds']}")


if __name__ == "__main__":
    main()
