"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from the sweep
JSONLs (results/dryrun_single.jsonl, results/dryrun_multi.jsonl)."""
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(path):
    rows = []
    full = os.path.join(ROOT, "results", path)
    if not os.path.exists(full):
        return rows
    with open(full) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


ARCH_ORDER = ["minitron-4b", "gemma-2b", "qwen3-8b", "h2o-danube-3-4b",
              "whisper-base", "rwkv6-3b", "qwen2-moe-a2.7b",
              "qwen3-moe-30b-a3b", "llama-3.2-vision-90b", "zamba2-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def skey(r):
    return (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99,
            SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 9)


def roofline_table(rows):
    out = ["| arch | shape | compute ms | HBM ms | ICI ms | dominant | "
           "MODEL/HLO | peak GiB/dev | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=skey):
        if r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skip | — | — | {r['why'][:46]} |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | |")
            continue
        fits = "yes" if r["peak_gib_dev"] < 16 else "**no**"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']:.1f} | "
            f"{r['memory_ms']:.1f} | {r['collective_ms']:.1f} | "
            f"**{r['dominant'][:4]}** | {r['useful_ratio']:.2f} | "
            f"{r['peak_gib_dev']:.1f} | {fits} |")
    return "\n".join(out)


def multipod_table(rows):
    out = ["| arch | shape | status | peak GiB/dev | compile s | "
           "collectives (rolled count) |",
           "|---|---|---|---|---|---|"]
    for r in sorted(rows, key=skey):
        if r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | skip (long_500k "
                       f"full-attn) | — | — | — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | **FAIL** | | | |")
            continue
        out.append(f"| {r['arch']} | {r['shape']} | ok | "
                   f"{r['peak_gib_dev']:.1f} | {r['t_compile_s']:.0f} | "
                   f"{int(r['coll_bytes_dev']/1e6)} MB permuted |")
    return "\n".join(out)


if __name__ == "__main__":
    single = load("dryrun_single.jsonl")
    multi = load("dryrun_multi.jsonl")
    print("## Single-pod roofline (paper-faithful posh backend)\n")
    print(roofline_table(single))
    print("\n## Multi-pod (2x16x16) compile proof\n")
    print(multipod_table(multi))
