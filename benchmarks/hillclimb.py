"""§Perf hillclimb driver: accounting-only variant runs for the three
selected cells.  Each record lands in results/hillclimb.jsonl with a
``variant`` tag; EXPERIMENTS.md §Perf is written from these.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import json
import sys

sys.path.insert(0, "src")

from repro.launch.dryrun import _depth_points, _EXTRAP_KEYS, lower_cell
from repro.launch import roofline, shapes
from repro import configs

OUT = "results/hillclimb.jsonl"


def acct(arch, shape_name, variant, pad_heads=None, **kw):
    cfg = configs.get(arch)
    if pad_heads:
        cfg = dataclasses.replace(cfg, n_heads=pad_heads)
    c1, c2, u1, u2, u_full = _depth_points(cfg)
    if pad_heads:
        c1 = dataclasses.replace(c1, n_heads=pad_heads)
        c2 = dataclasses.replace(c2, n_heads=pad_heads)
    a1 = lower_cell(arch, shape_name, unroll=True, cfg_override=c1,
                    verbose=False, **kw)
    a2 = lower_cell(arch, shape_name, unroll=True, cfg_override=c2,
                    verbose=False, **kw)
    out = dict(a1)
    scale = (u_full - u1) / (u2 - u1)
    for key in _EXTRAP_KEYS:
        out[key] = a1[key] + (a2[key] - a1[key]) * scale
    out["compute_ms"] = out["flops_dev"] / roofline.PEAK_FLOPS * 1e3
    out["memory_ms"] = out["bytes_dev"] / roofline.HBM_BW * 1e3
    out["collective_ms"] = out["coll_bytes_dev"] / roofline.LINK_BW * 1e3
    out["dominant"] = max(
        [("compute", out["compute_ms"]), ("memory", out["memory_ms"]),
         ("collective", out["collective_ms"])], key=lambda kv: kv[1])[0]
    # peak extrapolated from accounting passes (mb=1; upper bound)
    out["peak_gib_dev"] = a1["peak_gib_dev"] + \
        (a2["peak_gib_dev"] - a1["peak_gib_dev"]) * scale
    out["variant"] = variant
    rec = {k: v for k, v in out.items() if k != "coll_counts"}
    with open(OUT, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "variant", "compute_ms",
                       "memory_ms", "collective_ms", "dominant",
                       "peak_gib_dev")}))
    return out


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    runs = {
        # cell 2: qwen3 train_4k — collective term
        "qwen3_posh": lambda: acct("qwen3-8b", "train_4k",
                                   "baseline_posh_ring_zero1",
                                   backend="posh", zero=1),
        "qwen3_xla": lambda: acct("qwen3-8b", "train_4k",
                                  "xla_collectives_zero1",
                                  backend="xla", zero=1),
        "qwen3_zero0": lambda: acct("qwen3-8b", "train_4k",
                                    "xla_collectives_zero0",
                                    backend="xla", zero=0),
        # cell 1: minitron train_4k — memory term (padded-head layout)
        "minitron_pad": lambda: acct("minitron-4b", "train_4k",
                                     "padded_heads_32_head_layout",
                                     backend="posh", zero=1, pad_heads=32),
        # cell 3: qwen2-moe train_4k — dispatch collective
        "moe_a2a": lambda: acct("qwen2-moe-a2.7b", "train_4k",
                                "posh_alltoall_dispatch",
                                backend="posh", zero=1,
                                moe_dispatch="alltoall"),
        "moe_a2a_xla": lambda: acct("qwen2-moe-a2.7b", "train_4k",
                                    "xla_alltoall_dispatch",
                                    backend="xla", zero=1,
                                    moe_dispatch="alltoall"),
        # CE-mode lever on the small-vocab arch (gathered CE fits there)
        "danube_gathered": lambda: acct("h2o-danube-3-4b", "train_4k",
                                        "gathered_ce", backend="posh",
                                        zero=1, ce_mode="gathered"),
    }
    for name, fn in runs.items():
        if which != "all" and which != name:
            continue
        try:
            fn()
        except Exception as e:
            with open(OUT, "a") as f:
                f.write(json.dumps({"variant": name,
                                    "status": f"FAIL {e}"}) + "\n")
            print(f"{name} FAILED: {e}", file=sys.stderr)
    print("HILLCLIMB_DONE")
