"""Benchmark harness — one section per paper table.

  Table 1 (paper §5.1): memory-copy engine variants (VMEM tilings vs
          stock copy).  The stock path is the XLA:CPU fused copy; the
          Pallas variants are characterized structurally (working-set
          bytes — interpret-mode wall-clock is not hardware-indicative;
          correctness is covered in tests/test_kernels.py).
  Table 2 (§5.2): put/get latency/bandwidth through the full POSH layer
          vs a local device copy — 8 fake PEs in a subprocess.
  Table 3 (§5.3): POSH collectives vs native XLA collectives (the
          Berkeley-UPC/GASNet role), incl. the compile-time
          algorithm-selection comparison (§4.5.4).

Prints ``table,name,elems,us_per_call,derived`` CSV lines.
"""
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def bench_copy_variants():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, symm_copy

    print("table,op,elems,us_per_call,derived_gbps_or_vmem_kib")
    for elems in [4096, 262144, 4194304]:
        x = jnp.arange(elems, dtype=jnp.float32)
        fn = jax.jit(lambda v: ops.symm_copy(v, "stock"))
        for _ in range(3):
            jax.block_until_ready(fn(x))
        t0 = time.perf_counter()
        for _ in range(20):
            out = fn(x)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 20
        print(f"table1,copy_stock,{elems},{dt*1e6:.2f},"
              f"{elems*4/dt/1e9:.3f}")
        for variant in symm_copy.VARIANTS:
            kib = symm_copy.vmem_bytes(variant) / 1024
            print(f"table1,copy_{variant},{elems},nan,{kib:.0f}")


def bench_multipe():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "_worker.py")],
        capture_output=True, text=True, env=env, timeout=3600)
    if r.returncode != 0 or "WORKER_DONE" not in r.stdout:
        print("multipe worker FAILED", file=sys.stderr)
        print(r.stdout[-4000:], file=sys.stderr)
        print(r.stderr[-4000:], file=sys.stderr)
        raise SystemExit(1)
    for line in r.stdout.splitlines():
        if line and not line.startswith("WORKER_DONE"):
            print(line)


def bench_train_throughput():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat, configs
    from repro.data import SyntheticLM
    from repro.models import registry
    from repro.parallel.ctx import ParallelCtx, smap
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.step import make_train_step, train_state_specs

    ctx = ParallelCtx(dp_size=1, tp_size=1, sp=False, remat=True,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32)
    cfg = configs.get_smoke("qwen3-8b")
    api = registry.build(cfg)
    opt = AdamWConfig(lr=1e-3)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    sspecs = train_state_specs(cfg, ctx, api, opt)
    params = api.init(jax.random.PRNGKey(0), cfg, ctx)
    opt_state = smap(lambda p: adamw_init(p, ctx, opt), mesh,
                     (api.specs(cfg, ctx),), sspecs["opt"])(params)
    state = {"params": params, "opt": opt_state,
             "step": jnp.zeros((), jnp.int32)}
    fn = jax.jit(smap(make_train_step(cfg, ctx, api, opt), mesh,
                      (sspecs, {"tokens": P("data")}),
                      (sspecs, {"loss": P(), "grad_norm": P(),
                                "step": P()})))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=cfg.max_seq, global_batch=8)
    state, m = fn(state, data.batch(0))
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    steps = 5
    for s in range(1, steps + 1):
        state, m = fn(state, data.batch(s))
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / steps
    toks = 8 * cfg.max_seq
    print(f"train,smoke_step,{toks},{dt*1e6:.0f},{toks/dt:.0f}")


def main() -> None:
    bench_copy_variants()
    bench_multipe()
    bench_train_throughput()


if __name__ == "__main__":
    main()
