"""Serving benchmark — throughput / latency percentiles for the paged
continuous-batching engine, written to ``BENCH_serve.json`` at the REPO
ROOT (the bench trajectory the driver tracks):

    {"meta": {...},
     "results": [{"case", "arch", "backend", "attn_impl", "page_tokens",
                  "n_pages", "max_batch", "prefill_chunk", "sampling",
                  "temperature", "top_p", "requests", "tokens_out",
                  "throughput_tok_s", "latency_p50_s", "latency_p99_s",
                  "ttft_p50_s", "ttft_p99_s", "decode_p50_s",
                  "decode_p99_s", "preempted", "migrations"}, ...]}

Default sweep: page size x batch size x attention impl on the smoke
qwen3 config under the same seeded Poisson trace, plus a sampled
(top-p) sweep (``--sampling top_p`` rows), a chunked-vs-monolithic
prefill pair on the long-prompt mixed trace — the row pair that shows
chunked prefill protecting p99 decode latency — and SPECULATIVE-DECODE
rows (``spec_k > 0``, n-gram self-draft) on the REPEATED-PROMPT
workload, reporting ``spec_accept_rate`` and ``spec_tokens_per_tick``
(tokens one sequence's verify pass emits; > 1 = speculation beats
one-token-per-tick decode).  Attention-impl rows come in kernel/ref
PAIRS (``smoke``/``smoke_kernel``, ``p8_b4_ref``/``p8_b4_kernel``,
``repeated_spec_k2``/``repeated_spec_k2_kernel``) whose presence
``scripts/check_bench.py`` enforces, and so does the DISAGGREGATION
topology pair (``colocated``/``disagg_2p2d``): the same engine shape
and trace served monolithically vs split 2 prefill + 2 decode cells
with put-with-signal page handoff — disagg rows carry
``handoff_signals``/``handoff_quiets`` counters, and check_bench pins
``handoff_quiets`` to ZERO (per-transfer completion carries the whole
handoff load).  The CONTROL-PLANE pair (``router_host``/``router_amo``)
runs the same 2+2 disagg shape and trace with the router as the only
knob — host Python-loop scheduling vs lock-free CAS admission rings +
claim-word mailbox + symmetric page pool — and its amo row carries
``router_amos``/``router_quiets``/``steals``/``alloc_cas_retries``
(check_bench enforces the pair, equal token counts, and zero quiets on
the AMO path).

SLO rows (PR 10): the SATURATION sweep serves a fixed fleet-like class
mix (40% interactive / 20% batch / 40% best_effort, two tenants, tick-
unit deadlines) on the TICK clock at ramped arrival rates —
``sat_low`` .. ``sat_overload`` smoke endpoints, ``sat_r1/r2/r4`` ramp
rows in the full sweep — each row carrying per-class
``slo_attained_*`` / ``shed_*`` fields.  Because the tick clock makes
the whole schedule deterministic, check_bench gates these HARD:
interactive attainment >= 0.99 on every row (the protected SLO holds
through overload) and sheds land on best_effort ONLY; the full sweep
also records ``meta["saturation_knee_rate"]``, the rate where
best-effort shedding begins.  The HOT-SWAP pair
(``hot_swap_off``/``hot_swap_on``) serves one trace twice with the
in-flight weight swap as the only knob: the on row streams a second
weight generation between serving ticks and flips mid-run, and
check_bench pins equal token counts across the pair plus
``swap_extra_quiets == 0`` (the swap queue retires on per-transfer
signal/AMO waits, never a tick-global drain).  ``meta["sweep_cases"]``
lists every full-sweep case name under BOTH modes, so check_bench can
fail on committed rows the sweep no longer emits (RETIRED_CASES is the
allowlist).

``--smoke`` runs the smallest cases — one greedy, one
with the Pallas paged-attention KERNELS, one SAMPLED, one SPECULATIVE,
one DISAGGREGATED, the router pair, the saturation endpoints and the
hot-swap pair — so the `make verify` freshness
gate covers all serving modes end-to-end; the full sweep emits
the same smoke rows under the same case names, which is what lets
``scripts/check_bench.py`` match fresh smoke rows against the
committed file.

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke]
    PYTHONPATH=src python benchmarks/serve_bench.py --sampling top_p

On CPU the numbers measure the engine/scheduler structure, not
accelerator decode throughput (meta records the platform).
"""
import argparse
import json
import os
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
OUT = os.path.join(ROOT, "BENCH_serve.json")

SAMPLING = {                      # name -> (temperature, top_k, top_p)
    "greedy": (0.0, 0, 1.0),
    "top_k": (0.8, 8, 1.0),
    "top_p": (0.8, 0, 0.9),
}


def repeated_requests(n_requests, vocab, rate, seed, *, max_new=16,
                      sampling="greedy"):
    """The repeated-prompt workload speculation feeds on: periodic
    prompts (a short random pattern tiled to 12 tokens) that drive
    greedy decoding into self-repetition, where the n-gram self-draft
    proposer earns its accept rate.  Deterministic given the seed."""
    import numpy as np

    from repro import serve

    temp, top_k, top_p = SAMPLING[sampling]
    sp = serve.SamplingParams(temperature=temp, top_k=top_k, top_p=top_p)
    reqs, t = [], 0.0
    for i in range(n_requests):
        rng = np.random.RandomState(seed * 1000 + i)
        pattern = rng.randint(0, vocab, size=3 + i % 3).tolist()
        reqs.append(serve.Request(
            rid=i, prompt=(pattern * 8)[:12], max_new=max_new,
            t_arrive=t, sampling=sp))
        t += float(rng.exponential(1.0 / rate))
    return reqs


def audit_case_isolation(eng):
    """Per-case pool isolation: every case re-constructs its engine,
    and the engine's page pools must end SELF-CONTAINED — each cell's
    pages all back on its own free list/stack (or parked in that cell's
    prefix index), so a bench row can never alias page ids into the
    next case's freshly-built pools.  Runs after metrics are read and
    fails the bench loudly on a leak (a quiet leak here is exactly the
    cross-case aliasing the topology/router pairs would then measure)."""
    for cell in getattr(eng, "engines", [eng]):
        kv = cell.kv
        parked = sum(len(pages) for _, pages in kv._prefix.values())
        free = kv.n_free()
        if free + parked != kv.n_pages - 1:
            raise SystemExit(
                f"serve_bench: case left a non-conserved pool on a "
                f"{cell.role} cell — {free} free + {parked} prefix-"
                f"parked != {kv.n_pages - 1} grantable pages")


def run_case(case, arch, backend, attn_impl, page_tokens, n_pages,
             max_batch, n_requests, rate, seed, *, sampling="greedy",
             prefill_chunk=8, tick_tokens=0, long_frac=0.25,
             spec_k=0, workload="poisson", warmup=True, disagg="",
             router="host", slo=None, slo_traffic=None, hot_swap=None,
             clock="wall"):
    from repro import serve
    from repro.analysis import shmemcheck
    from repro.launch.serve import build_engine

    # isolate the (module-global) shmemcheck hooks per case: the
    # previous case's engine is garbage by now and CPython recycles
    # object ids, so stale per-queue checker state could alias onto
    # this case's freshly-built pool/mailbox queues
    shmemcheck.reset()
    eng, cfg = build_engine(arch, backend=backend,
                            page_tokens=page_tokens, n_pages=n_pages,
                            max_batch=max_batch, attn_impl=attn_impl,
                            prefill_chunk=prefill_chunk,
                            tick_tokens=tick_tokens, seed=seed,
                            spec_k=spec_k, disagg=disagg, router=router,
                            slo=(serve.SLOConfig(**slo)
                                 if slo is not None else None))
    temp, top_k, top_p = SAMPLING[sampling]

    def trace(seed_, n):
        if workload == "repeated":
            return repeated_requests(n, cfg.vocab, rate, seed_,
                                     sampling=sampling)
        tcfg = serve.TrafficConfig(n_requests=n, rate=rate,
                                   vocab=cfg.vocab, seed=seed_,
                                   long_frac=long_frac,
                                   temperature=temp, top_k=top_k,
                                   top_p=top_p, **(slo_traffic or {}))
        return serve.make_requests(tcfg)

    if warmup:
        # trigger every jit compile (prefill window, decode/verify,
        # sampler) on a throwaway mini-trace, then measure a clean run
        # on the same engine: rows reflect engine structure, not XLA
        # compiles
        eng.run(trace(seed + 1, 3), clock="wall")
        eng.reset_metrics()
    if hot_swap:
        # the hot_swap_on row: stream a SECOND weight generation (a
        # fresh init from seed+1000, the same derivation the CLI's
        # --hot-swap uses) into the live engine while the measured
        # trace is being served, flipping mid-run.  Token COUNTS must
        # match the off row exactly (the swap never sheds or stalls a
        # request) and the swap queue must retire on per-transfer
        # waits alone: swap_extra_quiets stays 0
        from repro.models import registry
        import jax as _jax
        ctx = getattr(eng, "ctx", None) or eng.engines[0].ctx
        new_params = registry.build(cfg).init(
            _jax.random.PRNGKey(seed + 1000), cfg, ctx)
        eng.begin_hot_swap(new_params)
    t0 = time.perf_counter()
    # explicit clock: ServeEngine and DisaggEngine default to different
    # clocks, and a topology row pair must share one.  SLO/saturation
    # and hot-swap rows run clock="tick" — deadlines and arrivals in
    # scheduler ticks — so attainment/shed numbers are DETERMINISTIC
    # and check_bench can gate them hard (>= 0.99), immune to CI wall-
    # clock jitter
    eng.run(trace(seed, n_requests), clock=clock)
    wall = time.perf_counter() - t0
    m = eng.metrics()
    row = {
        "case": case, "arch": cfg.name, "backend": backend,
        "attn_impl": attn_impl, "page_tokens": page_tokens,
        "n_pages": n_pages, "max_batch": max_batch,
        "prefill_chunk": prefill_chunk, "rate_req_s": rate,
        "sampling": sampling, "temperature": temp, "top_p": top_p,
        "workload": workload,
        "requests": m["requests"], "tokens_out": m["tokens_out"],
        "wall_s": round(wall, 4),
        "throughput_tok_s": round(m["throughput_tok_s"], 2),
        "latency_p50_s": round(m["latency_p50_s"], 4),
        "latency_p99_s": round(m["latency_p99_s"], 4),
        "ttft_p50_s": round(m["ttft_p50_s"], 4),
        "ttft_p99_s": round(m["ttft_p99_s"], 4),
        "decode_p50_s": round(m["decode_p50_s"], 4),
        "decode_p99_s": round(m["decode_p99_s"], 4),
        "preempted": m["sched"]["preempted"],
        "migrations": m["kv"]["migrations"],
        "spec_k": spec_k,
        "spec_accept_rate": round(m["spec"]["accept_rate"], 4),
        "spec_tokens_per_tick": round(m["spec"]["tokens_per_tick"], 4),
        "spec_drafted": m["spec"]["drafted"],
        "spec_emitted": m["spec"]["emitted"],
        "topology": disagg or "colocated",
        "router": router,
        "clock": clock,
    }
    if slo is not None:
        # per-class SLO fields only exist on SLO rows — check_bench
        # keys its saturation gate off slo_attained_interactive's
        # presence.  Shed counters land per class so the gate can pin
        # "sheds hit best_effort only"
        s = m["slo"]
        for cls in ("interactive", "batch", "best_effort"):
            row[f"slo_attained_{cls}"] = round(
                s["attained"].get(cls, 1.0), 4)
            row[f"shed_{cls}"] = s["shed"].get(cls, 0)
            row[f"finished_{cls}"] = s["finished"].get(cls, 0)
        pol = s.get("policy") or {}
        row["rate_deferred"] = pol.get("rate_deferred", 0)
        row["degraded_chunks"] = pol.get("degraded_chunks", 0)
    if hot_swap is not None:
        # both rows of the hot_swap pair carry the swap counters (the
        # off row all-zero): check_bench keys the pair gate off the
        # "hot_swap" field's presence
        sw = m["swap"]
        row.update(hot_swap=int(bool(hot_swap)),
                   swap_flips=sw["flips"],
                   swap_ticks=sw["swap_ticks"],
                   swap_batches=sw["swap_batches"],
                   swap_bytes=sw["swap_bytes"],
                   swap_extra_quiets=sw["swap_extra_quiets"])
    if disagg:
        # handoff counters only exist on disagg rows — check_bench
        # keys its topology gate off their presence.  The router/
        # allocator counters ride along (all zero in host mode): the
        # amo row's router_quiets is the lock-free no-barrier pin, and
        # steals/alloc_cas_retries are the contention trajectory
        h = m["handoff"]
        row.update(handoff_tickets=h["handoff_tickets"],
                   handoff_pages=h["handoff_pages"],
                   handoff_signals=h["handoff_signals"],
                   handoff_waits=h["handoff_waits"],
                   handoff_quiets=h["handoff_quiets"],
                   router_amos=h["router_amos"],
                   router_quiets=h["router_quiets"],
                   steals=h["steals"],
                   alloc_cas_retries=h["alloc_cas_retries"])
    audit_case_isolation(eng)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cases — greedy, kernel, sampled, "
                         "speculative, disagg, router host/amo pair — "
                         "refreshed IN PLACE inside the committed file "
                         "(verify-gate freshness)")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sampling", default="top_p",
                    choices=sorted(SAMPLING),
                    help="policy for the sampled sweep rows")
    args = ap.parse_args()

    import jax

    # (case, backend, impl, page_tokens, n_pages, max_batch, requests,
    #  sampling, extra engine kwargs)
    # the sampled smoke row must actually be non-greedy — it is what
    # gates the sampled path (top_k_merge + categorical draw) in `make
    # verify`; the spec smoke row gates the whole draft->verify->
    # accept->rewind loop (repeated-prompt workload, so its accept
    # rate is structurally > 0 and check_bench can enforce that).
    # SMOKE_CASES also open the full sweep under the SAME names: the
    # committed full file always contains the rows a fresh --smoke run
    # is compared against.
    sampled = args.sampling if args.sampling != "greedy" else "top_p"
    # the saturation sweep's shared SLO traffic shape: a fleet-like
    # class mix on the TICK clock (rate = requests/tick, deadlines in
    # ticks).  Interactive deadlines are the protected SLO; the tight
    # best-effort deadline is the pressure valve that starts shedding
    # once arrivals outrun capacity
    SAT_TRAFFIC = {"interactive_frac": 0.4, "batch_frac": 0.2,
                   "deadline_interactive": 100.0,
                   "deadline_batch": 200.0,
                   "deadline_best_effort": 6.0, "n_tenants": 2}
    SAT_KW = {"slo": {}, "slo_traffic": SAT_TRAFFIC, "clock": "tick"}
    SMOKE_CASES = [
        ("smoke", "xla", "ref", 4, 32, 3, 6, "greedy", {}),
        # the attn_impl kernel/ref PAIR: same engine shape as "smoke"
        # with the Pallas paged kernels on all three call sites
        # (decode + prefill/verify windows); check_bench enforces the
        # pair's presence
        ("smoke_kernel", "xla", "kernel", 4, 32, 3, 6, "greedy", {}),
        ("smoke_sampled", "xla", "ref", 4, 32, 3, 6, sampled, {}),
        ("smoke_spec", "xla", "ref", 4, 32, 3, 6, "greedy",
         {"spec_k": 3, "workload": "repeated"}),
        # the disagg smoke row: prefill and decode in separate cells
        # with the put-with-signal page handoff on the hot path — its
        # handoff_quiets counter is what check_bench pins to zero
        ("smoke_disagg", "xla", "ref", 4, 32, 3, 6, "greedy",
         {"disagg": "1+1"}),
        # the control-plane pair: identical 2+2 topology and trace,
        # the router is the ONLY knob — host Python-loop scheduling
        # vs CAS-arbitrated admission rings + claim-word mailbox +
        # symmetric page pools.  Token streams are bit-identical
        # (tier-1 pins the streams themselves; check_bench pins pair
        # presence, equal token counts, and zero quiets on both the
        # handoff and the router/allocator queues of the amo row)
        ("router_host", "xla", "ref", 4, 48, 3, 6, "greedy",
         {"disagg": "2+2"}),
        ("router_amo", "xla", "ref", 4, 48, 3, 6, "greedy",
         {"disagg": "2+2", "router": "amo"}),
        # the saturation pair the SLO gate rides on: the same class
        # mix under light load (sat_low) and overload (sat_overload —
        # arrivals far beyond tick capacity).  Interactive attainment
        # must hold >= 0.99 on BOTH; sheds may only land on
        # best_effort.  The full sweep ramps the rate between them
        ("sat_low", "xla", "ref", 4, 32, 3, 12, "greedy",
         dict(SAT_KW, rate=0.5)),
        ("sat_overload", "xla", "ref", 4, 32, 3, 12, "greedy",
         dict(SAT_KW, rate=8.0)),
        # the hot-swap pair: identical shape and trace on the tick
        # clock, the in-flight weight swap the ONLY knob.  check_bench
        # pins equal token counts across the pair and zero extra
        # global drains on the swap queue
        ("hot_swap_off", "xla", "ref", 4, 32, 3, 6, "greedy",
         {"hot_swap": False, "clock": "tick"}),
        ("hot_swap_on", "xla", "ref", 4, 32, 3, 6, "greedy",
         {"hot_swap": True, "clock": "tick"}),
    ]
    n = args.requests
    FULL_CASES = SMOKE_CASES + [
            ("p4_b2_ref", "xla", "ref", 4, 48, 2, n, "greedy", {}),
            ("p4_b4_ref", "xla", "ref", 4, 48, 4, n, "greedy", {}),
            ("p8_b4_ref", "xla", "ref", 8, 32, 4, n, "greedy", {}),
            ("p8_b4_kernel", "xla", "kernel", 8, 32, 4, n, "greedy", {}),
            ("p8_b4_posh", "posh", "ref", 8, 32, 4, n, "greedy", {}),
            # sampled sweep: the same engine shapes, non-greedy traffic
            ("p4_b4_" + args.sampling, "xla", "ref", 4, 48, 4, n,
             args.sampling, {}),
            ("p8_b4_" + args.sampling, "xla", "ref", 8, 32, 4, n,
             args.sampling, {}),
            # chunked-vs-monolithic prefill on the long-heavy mixed
            # trace under load: the structural probe for the token
            # budget protecting per-token DECODE latency (decode_p99 =
            # inter-token gaps, which a batch-mate's monolithic prompt
            # admission stretches).  NOTE: on the 2-layer CPU smoke
            # model the fused prefill window makes even a whole-prompt
            # call ~one decode tick, so the contrast here is within
            # noise — it grows with prefill compute per prompt (real
            # depths/lengths); the budget mechanics themselves are
            # pinned by the tier-1 scheduler tests.
            ("mixed_long_chunked", "xla", "ref", 4, 48, 4, 3 * n,
             "greedy", {"prefill_chunk": 8, "tick_tokens": 16,
                        "long_frac": 0.5, "rate": 32.0}),
            ("mixed_long_monolithic", "xla", "ref", 4, 48, 4, 3 * n,
             "greedy", {"prefill_chunk": 24, "long_frac": 0.5,
                        "rate": 32.0}),
            # speculative decoding on the repeated-prompt workload:
            # the spec_on/spec_off pair isolates what draft->verify
            # buys on self-repeating greedy streams (accept_rate and
            # tokens_per_tick are the structural wins; CPU wall time
            # grows with window width, the tick count shrinks), plus a
            # sampled spec row (acceptance is rarer — the draft must
            # hit the counter-RNG draw — but streams stay identical)
            ("repeated_spec_off", "xla", "ref", 4, 48, 4, n, "greedy",
             {"workload": "repeated"}),
            ("repeated_spec_k2", "xla", "ref", 4, 48, 4, n, "greedy",
             {"workload": "repeated", "spec_k": 2}),
            # the verify-window kernel under speculation: pairs with
            # repeated_spec_k2 the way p8_b4_kernel pairs with
            # p8_b4_ref (streams identical, only the attn impl moves)
            ("repeated_spec_k2_kernel", "xla", "kernel", 4, 48, 4, n,
             "greedy", {"workload": "repeated", "spec_k": 2}),
            ("repeated_spec_k4", "xla", "ref", 4, 48, 4, n, "greedy",
             {"workload": "repeated", "spec_k": 4}),
            ("repeated_spec_k4_" + args.sampling, "xla", "ref", 4, 48,
             4, n, args.sampling,
             {"workload": "repeated", "spec_k": 4}),
            # the disaggregation row pair: identical engine shape and
            # trace, topology is the ONLY knob — what page handoff
            # costs (TTFT, p99 decode) against the colocated engine,
            # with the signal/quiet counters showing the handoff load
            # rides per-transfer completion alone
            ("colocated", "xla", "ref", 4, 48, 3, n, "greedy", {}),
            ("disagg_2p2d", "xla", "ref", 4, 48, 3, n, "greedy",
             {"disagg": "2+2"}),
            # the saturation RAMP between the smoke endpoints: arrival
            # rate doubles per row, same class mix/deadlines/shape.
            # The knee — the first rate where best_effort starts
            # shedding — lands in meta["saturation_knee_rate"]
            ("sat_r1", "xla", "ref", 4, 32, 3, 12, "greedy",
             dict(SAT_KW, rate=1.0)),
            ("sat_r2", "xla", "ref", 4, 32, 3, 12, "greedy",
             dict(SAT_KW, rate=2.0)),
            ("sat_r4", "xla", "ref", 4, 32, 3, 12, "greedy",
             dict(SAT_KW, rate=4.0)),
        ]
    # the full sweep's case-name roster, emitted under BOTH modes: the
    # stale-case gate in check_bench compares the committed file
    # against this list, so retiring a case from the sweep without
    # allowlisting it in RETIRED_CASES fails verify loudly instead of
    # leaving a zombie row the gates still "check"
    sweep_cases = [c[0] for c in FULL_CASES]
    cases = SMOKE_CASES if args.smoke else FULL_CASES
    results = []
    for case, backend, impl, pt, np_, mb, nreq, sampling, extra in cases:
        extra = dict(extra)
        rate = extra.pop("rate", args.rate)
        row = run_case(case, args.arch, backend, impl, pt, np_, mb, nreq,
                       rate, args.seed, sampling=sampling, **extra)
        results.append(row)
        spec = (f"  accept {row['spec_accept_rate']:.2f} "
                f"tok/tick {row['spec_tokens_per_tick']:.2f}"
                if row["spec_k"] else "")
        if row["topology"] != "colocated":
            spec += (f"  [{row['topology']}] signals "
                     f"{row['handoff_signals']} quiets "
                     f"{row['handoff_quiets']}")
        if row["router"] == "amo":
            spec += (f"  [amo] amos {row.get('router_amos', 0)} "
                     f"steals {row.get('steals', 0)} "
                     f"cas_retries {row.get('alloc_cas_retries', 0)}")
        if "slo_attained_interactive" in row:
            spec += (f"  [slo] int {row['slo_attained_interactive']:.2f}"
                     f" shed_be {row['shed_best_effort']}")
        if "hot_swap" in row:
            spec += (f"  [swap {'on' if row['hot_swap'] else 'off'}] "
                     f"flips {row['swap_flips']} extra_quiets "
                     f"{row['swap_extra_quiets']}")
        print(f"{case:>22}: {row['throughput_tok_s']:8.1f} tok/s  "
              f"p50 {row['latency_p50_s']*1e3:7.1f} ms  "
              f"p99 {row['latency_p99_s']*1e3:7.1f} ms  "
              f"dec99 {row['decode_p99_s']*1e3:7.1f} ms  "
              f"preempt {row['preempted']}{spec}")

    if args.smoke and os.path.exists(OUT):
        # a smoke run REFRESHES its rows inside the committed file
        # instead of truncating the full-sweep trajectory down to 3
        # rows (a `make verify` must never destroy the other
        # baseline rows check_bench guards).  An unreadable existing
        # file fails LOUDLY here — quietly starting over would be
        # exactly the destruction this branch exists to prevent.
        with open(OUT) as f:
            old = json.load(f)
        fresh = {r["case"]: r for r in results}
        merged = [fresh.pop(r["case"], r)
                  for r in old.get("results", [])]
        results = merged + list(fresh.values())
        meta = old.get("meta", {})
        meta["smoke_refreshed"] = True
    else:
        meta = {"platform": jax.default_backend(),
                "smoke": bool(args.smoke), "rate_req_s": args.rate,
                "seed": args.seed, "sampling_sweep": args.sampling,
                "warmup": True,
                "note": "CPU rows measure engine/scheduler structure, "
                        "not accelerator decode throughput"}
    meta["sweep_cases"] = sweep_cases
    sat = sorted((r for r in results
                  if r["case"].startswith("sat_")
                  and "slo_attained_interactive" in r),
                 key=lambda r: r["rate_req_s"])
    if not args.smoke and sat:
        # the knee: the lowest arrival rate at which the policy starts
        # shedding best-effort traffic (interactive attainment is
        # gated to hold across the WHOLE ramp, so the knee is where
        # degradation begins, not where the protected SLO breaks)
        knee = next((r["rate_req_s"] for r in sat
                     if r["shed_best_effort"] > 0), None)
        meta["saturation_knee_rate"] = knee
        meta["saturation_rates"] = [r["rate_req_s"] for r in sat]
    with open(OUT, "w") as f:
        json.dump({"meta": meta, "results": results}, f, indent=1)
    print(f"wrote {OUT} ({len(results)} rows)")


if __name__ == "__main__":
    main()
