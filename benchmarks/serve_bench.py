"""Serving benchmark — throughput / latency percentiles for the paged
continuous-batching engine, written to ``BENCH_serve.json`` at the REPO
ROOT (the bench trajectory the driver tracks):

    {"meta": {...},
     "results": [{"case", "arch", "backend", "attn_impl", "page_tokens",
                  "n_pages", "max_batch", "requests", "tokens_out",
                  "throughput_tok_s", "latency_p50_s", "latency_p99_s",
                  "ttft_p50_s", "ttft_p99_s", "preempted",
                  "migrations"}, ...]}

Default sweep: page size x batch size x attention impl on the smoke
qwen3 config under the same seeded Poisson trace.  ``--smoke`` runs the
single smallest case (the `make verify` freshness gate — BENCH_serve
must exist and parse, not be a full sweep).

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke]

On CPU the numbers measure the engine/scheduler structure, not
accelerator decode throughput (meta records the platform).
"""
import argparse
import json
import os
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
OUT = os.path.join(ROOT, "BENCH_serve.json")


def run_case(case, arch, backend, attn_impl, page_tokens, n_pages,
             max_batch, n_requests, rate, seed):
    import jax

    from repro import serve
    from repro.launch.serve import build_engine

    eng, cfg = build_engine(arch, backend=backend,
                            page_tokens=page_tokens, n_pages=n_pages,
                            max_batch=max_batch, attn_impl=attn_impl,
                            seed=seed)
    tcfg = serve.TrafficConfig(n_requests=n_requests, rate=rate,
                               vocab=cfg.vocab, seed=seed)
    t0 = time.perf_counter()
    eng.run(serve.make_requests(tcfg))
    wall = time.perf_counter() - t0
    m = eng.metrics()
    return {
        "case": case, "arch": cfg.name, "backend": backend,
        "attn_impl": attn_impl, "page_tokens": page_tokens,
        "n_pages": n_pages, "max_batch": max_batch,
        "requests": m["requests"], "tokens_out": m["tokens_out"],
        "wall_s": round(wall, 4),
        "throughput_tok_s": round(m["throughput_tok_s"], 2),
        "latency_p50_s": round(m["latency_p50_s"], 4),
        "latency_p99_s": round(m["latency_p99_s"], 4),
        "ttft_p50_s": round(m["ttft_p50_s"], 4),
        "ttft_p99_s": round(m["ttft_p99_s"], 4),
        "preempted": m["sched"]["preempted"],
        "migrations": m["kv"]["migrations"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny case (verify-gate freshness)")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    if args.smoke:
        cases = [("smoke", "xla", "ref", 4, 32, 3, 6)]
    else:
        cases = [
            ("p4_b2_ref", "xla", "ref", 4, 48, 2, args.requests),
            ("p4_b4_ref", "xla", "ref", 4, 48, 4, args.requests),
            ("p8_b4_ref", "xla", "ref", 8, 32, 4, args.requests),
            ("p8_b4_kernel", "xla", "kernel", 8, 32, 4, args.requests),
            ("p8_b4_posh", "posh", "ref", 8, 32, 4, args.requests),
        ]
    results = []
    for case, backend, impl, pt, np_, mb, nreq in cases:
        row = run_case(case, args.arch, backend, impl, pt, np_, mb, nreq,
                       args.rate, args.seed)
        results.append(row)
        print(f"{case:>14}: {row['throughput_tok_s']:8.1f} tok/s  "
              f"p50 {row['latency_p50_s']*1e3:7.1f} ms  "
              f"p99 {row['latency_p99_s']*1e3:7.1f} ms  "
              f"preempt {row['preempted']}")

    payload = {
        "meta": {"platform": jax.default_backend(),
                 "smoke": bool(args.smoke), "rate_req_s": args.rate,
                 "seed": args.seed,
                 "note": "CPU rows measure engine/scheduler structure, "
                         "not accelerator decode throughput"},
        "results": results,
    }
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {OUT} ({len(results)} rows)")


if __name__ == "__main__":
    main()
