"""Serving benchmark — throughput / latency percentiles for the paged
continuous-batching engine, written to ``BENCH_serve.json`` at the REPO
ROOT (the bench trajectory the driver tracks):

    {"meta": {...},
     "results": [{"case", "arch", "backend", "attn_impl", "page_tokens",
                  "n_pages", "max_batch", "prefill_chunk", "sampling",
                  "temperature", "top_p", "requests", "tokens_out",
                  "throughput_tok_s", "latency_p50_s", "latency_p99_s",
                  "ttft_p50_s", "ttft_p99_s", "decode_p50_s",
                  "decode_p99_s", "preempted", "migrations"}, ...]}

Default sweep: page size x batch size x attention impl on the smoke
qwen3 config under the same seeded Poisson trace, plus a sampled
(top-p) sweep (``--sampling top_p`` rows) and a chunked-vs-monolithic
prefill pair on the long-prompt mixed trace — the row pair that shows
chunked prefill protecting p99 decode latency.  ``--smoke`` runs the
two smallest cases — one greedy, one SAMPLED (non-greedy), so the
`make verify` freshness gate covers a sampled run end-to-end.

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke]
    PYTHONPATH=src python benchmarks/serve_bench.py --sampling top_p

On CPU the numbers measure the engine/scheduler structure, not
accelerator decode throughput (meta records the platform).
"""
import argparse
import json
import os
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
OUT = os.path.join(ROOT, "BENCH_serve.json")

SAMPLING = {                      # name -> (temperature, top_k, top_p)
    "greedy": (0.0, 0, 1.0),
    "top_k": (0.8, 8, 1.0),
    "top_p": (0.8, 0, 0.9),
}


def run_case(case, arch, backend, attn_impl, page_tokens, n_pages,
             max_batch, n_requests, rate, seed, *, sampling="greedy",
             prefill_chunk=8, tick_tokens=0, long_frac=0.25,
             warmup=True):
    from repro import serve
    from repro.launch.serve import build_engine

    eng, cfg = build_engine(arch, backend=backend,
                            page_tokens=page_tokens, n_pages=n_pages,
                            max_batch=max_batch, attn_impl=attn_impl,
                            prefill_chunk=prefill_chunk,
                            tick_tokens=tick_tokens, seed=seed)
    temp, top_k, top_p = SAMPLING[sampling]
    tcfg = serve.TrafficConfig(n_requests=n_requests, rate=rate,
                               vocab=cfg.vocab, seed=seed,
                               long_frac=long_frac, temperature=temp,
                               top_k=top_k, top_p=top_p)
    if warmup:
        # trigger every jit compile (prefill window, decode, sampler)
        # on a throwaway mini-trace, then measure a clean run on the
        # same engine: rows reflect engine structure, not XLA compiles
        wcfg = serve.TrafficConfig(n_requests=3, rate=rate,
                                   vocab=cfg.vocab, seed=seed + 1,
                                   long_frac=long_frac,
                                   temperature=temp, top_k=top_k,
                                   top_p=top_p)
        eng.run(serve.make_requests(wcfg))
        eng.reset_metrics()
    t0 = time.perf_counter()
    eng.run(serve.make_requests(tcfg))
    wall = time.perf_counter() - t0
    m = eng.metrics()
    return {
        "case": case, "arch": cfg.name, "backend": backend,
        "attn_impl": attn_impl, "page_tokens": page_tokens,
        "n_pages": n_pages, "max_batch": max_batch,
        "prefill_chunk": prefill_chunk, "rate_req_s": rate,
        "sampling": sampling, "temperature": temp, "top_p": top_p,
        "requests": m["requests"], "tokens_out": m["tokens_out"],
        "wall_s": round(wall, 4),
        "throughput_tok_s": round(m["throughput_tok_s"], 2),
        "latency_p50_s": round(m["latency_p50_s"], 4),
        "latency_p99_s": round(m["latency_p99_s"], 4),
        "ttft_p50_s": round(m["ttft_p50_s"], 4),
        "ttft_p99_s": round(m["ttft_p99_s"], 4),
        "decode_p50_s": round(m["decode_p50_s"], 4),
        "decode_p99_s": round(m["decode_p99_s"], 4),
        "preempted": m["sched"]["preempted"],
        "migrations": m["kv"]["migrations"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two tiny cases, one greedy + one sampled "
                         "(verify-gate freshness)")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sampling", default="top_p",
                    choices=sorted(SAMPLING),
                    help="policy for the sampled sweep rows")
    args = ap.parse_args()

    import jax

    # (case, backend, impl, page_tokens, n_pages, max_batch, requests,
    #  sampling, extra engine kwargs)
    if args.smoke:
        # the sampled smoke row must actually be non-greedy — it is
        # what gates the sampled path (top_k_merge + categorical draw)
        # in `make verify`
        sampled = args.sampling if args.sampling != "greedy" else "top_p"
        cases = [
            ("smoke", "xla", "ref", 4, 32, 3, 6, "greedy", {}),
            ("smoke_sampled", "xla", "ref", 4, 32, 3, 6, sampled, {}),
        ]
    else:
        n = args.requests
        cases = [
            ("p4_b2_ref", "xla", "ref", 4, 48, 2, n, "greedy", {}),
            ("p4_b4_ref", "xla", "ref", 4, 48, 4, n, "greedy", {}),
            ("p8_b4_ref", "xla", "ref", 8, 32, 4, n, "greedy", {}),
            ("p8_b4_kernel", "xla", "kernel", 8, 32, 4, n, "greedy", {}),
            ("p8_b4_posh", "posh", "ref", 8, 32, 4, n, "greedy", {}),
            # sampled sweep: the same engine shapes, non-greedy traffic
            ("p4_b4_" + args.sampling, "xla", "ref", 4, 48, 4, n,
             args.sampling, {}),
            ("p8_b4_" + args.sampling, "xla", "ref", 8, 32, 4, n,
             args.sampling, {}),
            # chunked-vs-monolithic prefill on the long-heavy mixed
            # trace under load: the structural probe for the token
            # budget protecting per-token DECODE latency (decode_p99 =
            # inter-token gaps, which a batch-mate's monolithic prompt
            # admission stretches).  NOTE: on the 2-layer CPU smoke
            # model the fused prefill window makes even a whole-prompt
            # call ~one decode tick, so the contrast here is within
            # noise — it grows with prefill compute per prompt (real
            # depths/lengths); the budget mechanics themselves are
            # pinned by the tier-1 scheduler tests.
            ("mixed_long_chunked", "xla", "ref", 4, 48, 4, 3 * n,
             "greedy", {"prefill_chunk": 8, "tick_tokens": 16,
                        "long_frac": 0.5, "rate": 32.0}),
            ("mixed_long_monolithic", "xla", "ref", 4, 48, 4, 3 * n,
             "greedy", {"prefill_chunk": 24, "long_frac": 0.5,
                        "rate": 32.0}),
        ]
    results = []
    for case, backend, impl, pt, np_, mb, nreq, sampling, extra in cases:
        extra = dict(extra)
        rate = extra.pop("rate", args.rate)
        row = run_case(case, args.arch, backend, impl, pt, np_, mb, nreq,
                       rate, args.seed, sampling=sampling, **extra)
        results.append(row)
        print(f"{case:>22}: {row['throughput_tok_s']:8.1f} tok/s  "
              f"p50 {row['latency_p50_s']*1e3:7.1f} ms  "
              f"p99 {row['latency_p99_s']*1e3:7.1f} ms  "
              f"dec99 {row['decode_p99_s']*1e3:7.1f} ms  "
              f"preempt {row['preempted']}")

    payload = {
        "meta": {"platform": jax.default_backend(),
                 "smoke": bool(args.smoke), "rate_req_s": args.rate,
                 "seed": args.seed, "sampling_sweep": args.sampling,
                 "warmup": True,
                 "note": "CPU rows measure engine/scheduler structure, "
                         "not accelerator decode throughput"},
        "results": results,
    }
    with open(OUT, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {OUT} ({len(results)} rows)")


if __name__ == "__main__":
    main()
