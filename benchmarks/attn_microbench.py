"""Paged-attention microbenchmark — the numbers behind ``choose_block``.

Sweeps the DECODE and PREFILL-WINDOW Pallas kernels against their jnp
oracles over window / page / dtype shapes and writes ``BENCH_attn.json``
at the REPO ROOT (a bench trajectory the driver tracks):

    {"meta": {...},
     "results": [{"case", "kind", "window", "page_tokens", "slots",
                  "heads", "kv_heads", "head_dim", "dtype", "impl",
                  "block_q", "us_per_call", "max_err_vs_ref",
                  "err_tol"}, ...],
     "chosen": [{"window", "dtype", "chosen_block_q",
                 "candidates_us", "fastest_block_q"}, ...]}

Every kernel row records ``max_err_vs_ref`` on the exact inputs it was
timed on — parity is part of the trajectory, so a numerics regression
fails ``scripts/check_bench.py`` even if timing looks fine.  The
``chosen`` section times every q-block candidate per (window, dtype)
and records what ``paged_attention.choose_block`` picks next to the
measured fastest — the cross-check for the §4.5.4 dispatch ladder
(re-tune the ladder from this file, the same loop as
``DispatchTable.tuned_from_bench`` for the comm schedules).

``--smoke`` runs one decode pair and two prefill-window pairs (the
chunk shape and the spec-verify shape) and refreshes those rows IN
PLACE inside the committed file — the `make verify` freshness gate.
The full sweep emits the same case names, so fresh smoke rows always
find their committed counterparts.

    PYTHONPATH=src python benchmarks/attn_microbench.py [--smoke]

Off-TPU the kernels run the Pallas interpreter: rows measure kernel
STRUCTURE (and parity), not accelerator throughput — meta records the
platform, and check_bench's timing floor absorbs the noise.
"""
import argparse
import json
import os
import time

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
OUT = os.path.join(ROOT, "BENCH_attn.json")

B, H, HKV, D = 4, 4, 2, 16
DTYPES = {"f32": "float32", "bf16": "bfloat16"}
ERR_TOL = {"f32": 1e-5, "bf16": 3e-2}

# (kind, window, page_tokens, slots, dtype-tag); smoke = the shapes the
# serving engine actually runs per tick (prefill chunk + verify window
# + decode), full adds the size/dtype axes behind the dispatch ladder
SMOKE_CASES = [
    ("decode", None, 4, 8, "f32"),
    ("prefill", 8, 4, 8, "f32"),       # the default chunked-prefill tick
    ("prefill", 4, 4, 8, "f32"),       # the (B, spec_k+1) verify window
]
FULL_CASES = SMOKE_CASES + [
    ("decode", None, 8, 4, "f32"),
    ("decode", None, 4, 8, "bf16"),
    ("prefill", 8, 8, 4, "f32"),
    ("prefill", 16, 4, 8, "f32"),
    ("prefill", 32, 8, 8, "f32"),
    ("prefill", 8, 4, 8, "bf16"),
    ("prefill", 32, 8, 8, "bf16"),
]
CHOSEN_SWEEP = [(8, "f32"), (32, "f32"), (64, "f32"), (32, "bf16")]
CANDIDATES = (8, 16, 32, 64)


def case_name(kind, window, page_tokens, dt):
    if kind == "decode":
        return f"decode_p{page_tokens}_{dt}"
    return f"prefill_w{window}_p{page_tokens}_{dt}"


def _timeit(fn, warmup=1, reps=3):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6      # us/call


def _inputs(kind, window, page_tokens, slots, dtype, seed=0):
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.RandomState(seed)
    n_pages = B * slots + 1
    kp = jnp.asarray(rng.randn(n_pages, page_tokens, HKV, D)).astype(dtype)
    vp = jnp.asarray(rng.randn(n_pages, page_tokens, HKV, D)).astype(dtype)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_pages))
                     .reshape(B, slots).astype(np.int32))
    span = page_tokens * slots
    if kind == "decode":
        q = jnp.asarray(rng.randn(B, H, D)).astype(dtype)
        lens = jnp.asarray(rng.randint(1, span + 1, B), jnp.int32)
        return q, kp, vp, bt, lens
    q = jnp.asarray(rng.randn(B, window, H, D)).astype(dtype)
    start = jnp.asarray(rng.randint(0, span - window + 1, B), jnp.int32)
    n_tok = jnp.asarray(rng.randint(1, window + 1, B), jnp.int32)
    return q, kp, vp, bt, start, n_tok


def run_pair(kind, window, page_tokens, slots, dt, *, block_q=None):
    """Time kernel + ref on identical inputs; returns the two rows."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    dtype = jnp.dtype(DTYPES[dt])
    args = _inputs(kind, window, page_tokens, slots, dtype)
    op = ops.paged_attention if kind == "decode" \
        else ops.paged_prefill_attention
    kw = {} if kind == "decode" else {"block_q": block_q}
    ker = lambda: op(*args, impl="kernel", **kw)
    ref = lambda: op(*args, impl="ref")
    err = float(np.max(np.abs(np.asarray(ker(), np.float32)
                              - np.asarray(ref(), np.float32))))
    name = case_name(kind, window, page_tokens, dt)
    common = dict(kind=kind, window=window, page_tokens=page_tokens,
                  slots=slots, heads=H, kv_heads=HKV, head_dim=D,
                  dtype=DTYPES[dt])
    return [
        dict(case=name + "_kernel", impl="kernel", block_q=block_q,
             us_per_call=round(_timeit(ker), 1), max_err_vs_ref=err,
             err_tol=ERR_TOL[dt], **common),
        dict(case=name + "_ref", impl="ref", block_q=None,
             us_per_call=round(_timeit(ref), 1), max_err_vs_ref=0.0,
             err_tol=ERR_TOL[dt], **common),
    ]


def sweep_chosen():
    """Time every q-block candidate per (window, dtype) and record the
    dispatch ladder's pick next to the measured fastest."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels import paged_attention as pa

    out = []
    for window, dt in CHOSEN_SWEEP:
        dtype = jnp.dtype(DTYPES[dt])
        args = _inputs("prefill", window, 8, max(2, window // 4), dtype)
        cand_us = {}
        for bq in CANDIDATES:
            if bq > -(-window // 8) * 8 * 2:     # pointless oversizing
                continue
            cand_us[str(bq)] = round(_timeit(
                lambda: ops.paged_prefill_attention(
                    *args, impl="kernel", block_q=bq)), 1)
        fastest = min(cand_us, key=cand_us.get)
        out.append(dict(window=window, dtype=DTYPES[dt],
                        chosen_block_q=pa.choose_block(window, dtype),
                        candidates_us=cand_us,
                        fastest_block_q=int(fastest)))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="decode + chunk + verify pairs only, rows "
                         "refreshed IN PLACE inside the committed file")
    args = ap.parse_args()

    import jax

    cases = SMOKE_CASES if args.smoke else FULL_CASES
    results = []
    for kind, window, pt, slots, dt in cases:
        rows = run_pair(kind, window, pt, slots, dt)
        results.extend(rows)
        k, r = rows
        print(f"{k['case']:>26}: kernel {k['us_per_call']:10.1f} us  "
              f"ref {r['us_per_call']:10.1f} us  "
              f"err {k['max_err_vs_ref']:.2e}")

    if args.smoke and os.path.exists(OUT):
        # refresh smoke rows inside the committed trajectory instead of
        # truncating the full sweep (same contract as serve_bench; an
        # unreadable file fails LOUDLY rather than starting over)
        with open(OUT) as f:
            old = json.load(f)
        fresh = {r["case"]: r for r in results}
        merged = [fresh.pop(r["case"], r)
                  for r in old.get("results", [])]
        results = merged + list(fresh.values())
        chosen = old.get("chosen", [])
        meta = old.get("meta", {})
        meta["smoke_refreshed"] = True
    else:
        chosen = sweep_chosen()
        for c in chosen:
            print(f"chosen w={c['window']:>3} {c['dtype']}: ladder "
                  f"{c['chosen_block_q']} fastest {c['fastest_block_q']} "
                  f"{c['candidates_us']}")
        meta = {"platform": jax.default_backend(),
                "smoke": bool(args.smoke),
                "shape": {"B": B, "H": H, "Hkv": HKV, "D": D},
                "note": "off-TPU rows run the Pallas interpreter: they "
                        "measure kernel structure and parity, not "
                        "accelerator throughput"}
    with open(OUT, "w") as f:
        json.dump({"meta": meta, "results": results, "chosen": chosen},
                  f, indent=1)
    print(f"wrote {OUT} ({len(results)} rows)")


if __name__ == "__main__":
    main()
